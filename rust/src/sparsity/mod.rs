//! Activation-sparsity substrate: neuron temperature distributions,
//! batch-aggregated activation statistics (Fig.2), the online activation
//! predictor's quality model, and LRU cache-hit analysis (Che's
//! approximation) used by the cache and planner.
//!
//! The paper derives these statistics by tracing 10M+ tokens of Wikipedia/
//! RefinedWeb through each model (§5). That trace is not available here, so
//! [`ActivationModel`] generates a calibrated temperature distribution
//! with the same macroscopic properties the paper reports:
//!
//!   * a tiny hot set (<1% of neurons at batch 1) carrying most accesses,
//!   * batch aggregation: a neuron is "activated" if at least one token in
//!     the batch fires it, so the highly-activated share grows from <1%
//!     (B=1) to ~75% (B=32) — Fig.2,
//!   * 80% Gate/Up/Down bundle co-activation; <20% residual co-activation
//!     among cold neurons after hot removal (§4.2, §4.4).

use crate::config::ModelSpec;
use crate::util::prng::Rng;

/// Number of representative neurons used to model a layer's temperature
/// distribution (each represents `inter·experts / N_REP` real neurons).
pub const N_REP: usize = 2048;

/// Per-model neuron temperature model.
#[derive(Debug, Clone)]
pub struct ActivationModel {
    /// Per-token activation probability of each representative neuron,
    /// sorted descending (index 0 = hottest).
    probs: Vec<f64>,
    /// How many real neurons each representative stands for.
    pub neurons_per_rep: f64,
    /// Gate/Up/Down cross-matrix co-activation probability.
    pub bundle_coactivation: f64,
}

impl ActivationModel {
    /// Build the calibrated distribution for a model spec.
    pub fn for_model(spec: &ModelSpec, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5041_4253);
        let hot_frac = spec.hot_frac_b1;
        let s = spec.sparsity_active_frac;
        // Hot set: p ∈ [0.85, 0.98]. Cold set: lognormal with σ=0.42 and
        // mean chosen so the whole distribution averages to `s`. The σ is
        // fitted so that most neurons clear the "highly activated"
        // threshold at batch 32 when s ≈ 0.11 (Fig.2's Bamboo-7B panel).
        let hot_n = ((N_REP as f64) * hot_frac).round() as usize;
        let cold_mean = ((s - hot_frac * 0.92) / (1.0 - hot_frac)).max(1e-4);
        let sigma = 0.70;
        let mu = cold_mean.ln() - sigma * sigma / 2.0;
        let mut probs = Vec::with_capacity(N_REP);
        for i in 0..N_REP {
            let p = if i < hot_n {
                0.85 + 0.13 * rng.f64()
            } else {
                (mu + sigma * rng.normal()).exp().clamp(1e-4, 0.80)
            };
            probs.push(p);
        }
        probs.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let total = spec.neurons_per_layer() as f64;
        ActivationModel {
            probs,
            neurons_per_rep: total / N_REP as f64,
            bundle_coactivation: spec.bundle_coactivation,
        }
    }

    /// Representative per-token activation probabilities (descending).
    pub fn probs(&self) -> &[f64] {
        &self.probs
    }

    /// P(neuron rep i activated by ≥1 token of a size-`batch` batch).
    pub fn batch_prob(&self, i: usize, batch: usize) -> f64 {
        1.0 - (1.0 - self.probs[i]).powi(batch as i32)
    }

    /// Mean fraction of neurons activated under a batch (Fig.2 aggregate).
    pub fn active_frac(&self, batch: usize) -> f64 {
        self.probs
            .iter()
            .map(|p| 1.0 - (1.0 - p).powi(batch as i32))
            .sum::<f64>()
            / N_REP as f64
    }

    /// Fraction of neurons that are "highly activated" (batch-aggregated
    /// activation probability above `thresh`) — the white region of Fig.2.
    pub fn hot_share(&self, batch: usize, thresh: f64) -> f64 {
        self.probs
            .iter()
            .filter(|&&p| 1.0 - (1.0 - p).powi(batch as i32) > thresh)
            .count() as f64
            / N_REP as f64
    }

    /// Fraction of all *activations* covered by the hottest `frac` of
    /// neurons at the given batch size (planner coverage curve, §5).
    pub fn coverage_of_top(&self, frac: f64, batch: usize) -> f64 {
        let k = ((N_REP as f64) * frac).round() as usize;
        let total: f64 = (0..N_REP).map(|i| self.batch_prob(i, batch)).sum();
        if total == 0.0 {
            return 0.0;
        }
        (0..k.min(N_REP)).map(|i| self.batch_prob(i, batch)).sum::<f64>() / total
    }

    /// Mean per-step activation probability of the *cold* region when the
    /// hottest `hot_frac` of neurons are pinned hot.
    pub fn cold_active_frac(&self, hot_frac: f64, batch: usize) -> f64 {
        let k = ((N_REP as f64) * hot_frac).round() as usize;
        if k >= N_REP {
            return 0.0;
        }
        (k..N_REP)
            .map(|i| self.batch_prob(i, batch))
            .sum::<f64>()
            / (N_REP - k) as f64
    }

    /// Sample the number of activated cold neurons for one decode step in
    /// one layer (real-neuron units).
    pub fn sample_cold_active(
        &self,
        hot_frac: f64,
        batch: usize,
        rng: &mut Rng,
    ) -> u64 {
        let k = ((N_REP as f64) * hot_frac).round() as usize;
        let mut count = 0.0;
        for i in k..N_REP {
            let p = self.batch_prob(i, batch);
            // each representative stands for neurons_per_rep neurons
            count += rng.binomial(self.neurons_per_rep.round() as usize, p) as f64;
        }
        count as u64
    }

    /// Fig.2 heat grid: rows = batch sizes, cols = neuron deciles (hottest
    /// first), value = mean batch-aggregated activation frequency.
    pub fn heat_grid(&self, batches: &[usize], deciles: usize) -> Vec<Vec<f64>> {
        let per = N_REP / deciles;
        batches
            .iter()
            .map(|&b| {
                (0..deciles)
                    .map(|d| {
                        let lo = d * per;
                        let hi = (lo + per).min(N_REP);
                        (lo..hi).map(|i| self.batch_prob(i, b)).sum::<f64>()
                            / (hi - lo) as f64
                    })
                    .collect()
            })
            .collect()
    }
}

/// Quality model of the online activation predictor (§3.2: PowerInfer-2
/// reuses PowerInfer/LLMFlash-style low-rank MLP predictors on the CPU
/// side).
#[derive(Debug, Clone, Copy)]
pub struct PredictorModel {
    /// P(active neuron is predicted active) — misses cost accuracy, and
    /// the paper reports negligible degradation, so recall is high.
    pub recall: f64,
    /// Extra inactive neurons predicted active, as a fraction of the true
    /// active count (wasted compute + I/O).
    pub false_positive_overhead: f64,
    /// Low-rank dimension (drives predictor FLOPs).
    pub rank: usize,
}

impl Default for PredictorModel {
    fn default() -> Self {
        PredictorModel { recall: 0.97, false_positive_overhead: 0.12, rank: 256 }
    }
}

impl PredictorModel {
    /// Neurons the CPU will actually *compute* given `active` truly-active
    /// cold neurons.
    pub fn predicted_count(&self, active: u64) -> u64 {
        (active as f64 * self.recall * (1.0 + self.false_positive_overhead))
            .round() as u64
    }

    /// FLOPs per token per layer for running the predictor.
    pub fn flops(&self, hidden: usize, inter: usize, batch: usize) -> f64 {
        2.0 * batch as f64 * (hidden * self.rank + self.rank * inter) as f64
    }
}

/// Che's approximation for LRU hit rates: given per-step access
/// probabilities `q` (each representing `weight` objects) and a capacity,
/// solve Σ 1-(1-q_i)^T = C for the characteristic time T, then
/// hit_i = 1-(1-q_i)^T.
pub fn lru_hit_rate(q: &[(f64, f64)], capacity: f64) -> f64 {
    let total_objects: f64 = q.iter().map(|(_, w)| w).sum();
    if capacity >= total_objects {
        return 1.0;
    }
    if capacity <= 0.0 {
        return 0.0;
    }
    // binary search on T (steps)
    let occupancy = |t: f64| -> f64 {
        q.iter()
            .map(|(qi, w)| w * (1.0 - (1.0 - qi).powf(t)))
            .sum::<f64>()
    };
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    while occupancy(hi) < capacity && hi < 1e12 {
        hi *= 2.0;
    }
    for _ in 0..64 {
        let mid = 0.5 * (lo + hi);
        if occupancy(mid) < capacity {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let t = 0.5 * (lo + hi);
    let access_total: f64 = q.iter().map(|(qi, w)| qi * w).sum();
    if access_total == 0.0 {
        return 1.0;
    }
    q.iter()
        .map(|(qi, w)| qi * w * (1.0 - (1.0 - qi).powf(t)))
        .sum::<f64>()
        / access_total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{bamboo_7b, mistral_7b_silu};

    fn bamboo_model() -> ActivationModel {
        ActivationModel::for_model(&bamboo_7b(), 1)
    }

    #[test]
    fn batch1_activation_matches_model_sparsity() {
        let m = bamboo_model();
        let f = m.active_frac(1);
        assert!((f - 0.11).abs() < 0.02, "active frac {f}");
    }

    #[test]
    fn fig2_hot_share_grows_from_under_1pct_to_about_75pct() {
        // Fig.2: highly-activated share <1% at batch 1, ~75% at batch 32.
        let m = bamboo_model();
        let b1 = m.hot_share(1, 0.85);
        let b32 = m.hot_share(32, 0.90);
        assert!(b1 < 0.02, "b1 hot share {b1}");
        assert!((0.55..0.92).contains(&b32), "b32 hot share {b32}");
    }

    #[test]
    fn heat_grid_is_monotone_in_batch_and_rank() {
        let m = bamboo_model();
        let grid = m.heat_grid(&[1, 4, 16, 32], 10);
        // monotone in batch (column-wise)
        for c in 0..10 {
            for r in 1..4 {
                assert!(grid[r][c] >= grid[r - 1][c] - 1e-12);
            }
        }
        // monotone in neuron rank (row-wise, hottest decile first)
        for row in &grid {
            for c in 1..10 {
                assert!(row[c] <= row[c - 1] + 1e-12);
            }
        }
    }

    #[test]
    fn silu_model_is_much_denser() {
        let relu = bamboo_model();
        let silu = ActivationModel::for_model(&mistral_7b_silu(), 1);
        assert!(silu.active_frac(1) > 2.5 * relu.active_frac(1));
        assert!((silu.active_frac(1) - 0.5).abs() < 0.06);
    }

    #[test]
    fn top_neurons_cover_most_activations() {
        // skewed temperature: the hottest 20% must cover well over 20%
        // of activations at batch 1.
        let m = bamboo_model();
        let cov = m.coverage_of_top(0.2, 1);
        assert!(cov > 0.45, "coverage {cov}");
        // and coverage is monotone in the fraction
        assert!(m.coverage_of_top(0.5, 1) > cov);
        assert!((m.coverage_of_top(1.0, 1) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cold_region_is_sparser_than_whole() {
        let m = bamboo_model();
        let whole = m.active_frac(1);
        let cold = m.cold_active_frac(0.3, 1);
        assert!(cold < whole, "cold {cold} vs whole {whole}");
    }

    #[test]
    fn sampled_cold_count_matches_expectation() {
        let m = bamboo_model();
        let mut rng = Rng::new(9);
        let hot_frac = 0.3;
        let n: u64 = 200;
        let total: u64 = (0..n).map(|_| m.sample_cold_active(hot_frac, 1, &mut rng)).sum();
        let mean = total as f64 / n as f64;
        let cold_neurons = (1.0 - hot_frac) * m.neurons_per_rep * N_REP as f64;
        let expected = m.cold_active_frac(hot_frac, 1) * cold_neurons;
        assert!((mean - expected).abs() / expected < 0.05,
                "mean {mean} vs expected {expected}");
    }

    #[test]
    fn predictor_counts() {
        let p = PredictorModel::default();
        let n = p.predicted_count(1000);
        assert!((1000..1200).contains(&n), "{n}");
        assert!(p.flops(4096, 14336, 1) > 0.0);
    }

    #[test]
    fn lru_hit_rate_limits() {
        let q: Vec<(f64, f64)> = (0..100).map(|i| (0.5 / (i as f64 + 1.0), 10.0)).collect();
        assert_eq!(lru_hit_rate(&q, 1000.0), 1.0); // cache ≥ universe
        assert_eq!(lru_hit_rate(&q, 0.0), 0.0);
        let half = lru_hit_rate(&q, 500.0);
        assert!(half > 0.5 && half < 1.0, "{half}");
        // monotone in capacity
        assert!(lru_hit_rate(&q, 700.0) > half);
    }

    #[test]
    fn lru_prefers_hot_objects() {
        // a cache holding exactly the hot half should hit far more often
        // than uniform popularity would suggest
        let mut q: Vec<(f64, f64)> = vec![(0.9, 50.0), (0.01, 50.0)];
        let hit = lru_hit_rate(&q, 50.0);
        assert!(hit > 0.9, "{hit}");
        q.reverse(); // order must not matter
        assert!((lru_hit_rate(&q, 50.0) - hit).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = ActivationModel::for_model(&bamboo_7b(), 7);
        let b = ActivationModel::for_model(&bamboo_7b(), 7);
        assert_eq!(a.probs()[..16], b.probs()[..16]);
    }
}
