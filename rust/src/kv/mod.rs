//! Paged KV cache: a shared block pool with prefix sharing — the serving
//! analog of the segmented neuron cache (§4.2).
//!
//! PowerInfer-2's central move is fine-grained, demand-driven memory
//! management (cluster-granular neuron residency); this module applies
//! the same idea to KV state. Instead of each decode slot statically
//! owning a dense `[seq_max]` cache row, every sequence holds a
//! [`KvLease`]: an ordered list of fixed-size blocks drawn from one
//! shared, refcounted [`KvPool`]:
//!
//! ```text
//!   admit(prompt) ──▶ KvLease { blocks: [3, 7, 9], len: 37 }
//!                               │   │   └─ private tail (partial)
//!                               └───┴──── full blocks, shareable
//!   pool:  [R][·][·][3*][·][·][·][7*][·][9]...   (R = reserved scratch)
//! ```
//!
//! - **Allocation** is free-list based and O(1) per block; a sequence
//!   grows one block at a time as it decodes and returns every block at
//!   [`KvPool::release`] — no drain barrier, no per-slot ceiling beyond
//!   the block-table width.
//! - **Prefix sharing**: full prompt blocks are content-addressed by a
//!   position-anchored chain hash of their token ids. Two requests with
//!   a common prompt prefix map the shared prefix to the *same physical
//!   blocks* (refcounted), so N copies of a system prompt cost one.
//! - **Copy-on-write**: a lease forked from another ([`KvPool::fork`])
//!   shares all blocks; the first append to a shared tail block copies
//!   it at block granularity and rewrites only the writer's mapping.
//!
//! The pool is pure bookkeeping — engines own the actual KV tensors
//! (device-side, `[num_blocks, block_tokens, kv_heads, head_dim]` per
//! layer) and consume the lease's block list as the per-row block table
//! of the decode graphs.

use std::collections::HashMap;

/// Physical block 0 is never leased: it is the scratch block that vacant
/// batch rows of a decode graph scribble into (their writes are masked).
pub const RESERVED_BLOCK: u32 = 0;

/// Typed allocation failure, preserved through `anyhow` so schedulers can
/// tell "pool pressure, retry after a retire" from a real error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvPoolError {
    /// Not enough free blocks for the allocation (plus requested reserve).
    Exhausted { needed: usize, free: usize },
    /// The lease would exceed the block-table width of the compiled
    /// decode graphs (`max_blocks_per_seq`).
    WindowExceeded { blocks: usize, max_blocks: usize },
}

impl std::fmt::Display for KvPoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvPoolError::Exhausted { needed, free } => write!(
                f,
                "KV pool exhausted: {needed} blocks needed, {free} free"
            ),
            KvPoolError::WindowExceeded { blocks, max_blocks } => write!(
                f,
                "KV lease of {blocks} blocks exceeds the {max_blocks}-block \
                 table of the compiled decode graphs"
            ),
        }
    }
}

impl std::error::Error for KvPoolError {}

/// Typed invariant-violation error raised by the machine-checkable
/// audits ([`KvPool::check_invariants`] and the engine/coordinator
/// `check_invariants` built on it). Kept downcastable through `anyhow`
/// so the model checker (`pi2 check`) can tell a broken invariant from
/// an ordinary serving error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InvariantViolation(pub String);

impl std::fmt::Display for InvariantViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invariant violated: {}", self.0)
    }
}

impl std::error::Error for InvariantViolation {}

/// Build a typed, downcastable [`InvariantViolation`] — `Error::new`
/// with a concrete type, never a bare string.
pub fn violation(msg: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(InvariantViolation(msg.into()))
}

/// Copy-on-write hop returned by [`KvPool::append`]: the engine must copy
/// the KV contents of physical block `src` into `dst` (all layers) before
/// the next decode step writes through the new mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CowCopy {
    pub src: u32,
    pub dst: u32,
}

/// What one append decided: where the token's KV entry will land, and
/// whether a shared tail block had to be copied first.
#[derive(Debug, Clone, Copy)]
pub struct KvAppend {
    /// Physical block receiving the new token.
    pub block: u32,
    /// Slot within the block (`pos % block_tokens`).
    pub slot: usize,
    /// Set when a copy-on-write detach happened.
    pub cow: Option<CowCopy>,
}

/// Compact lease summary carried on [`crate::serve::Admission`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvLeaseInfo {
    /// Blocks mapped by the lease at admission.
    pub blocks: usize,
    /// Leading blocks reused from another lease's identical prompt prefix.
    pub shared_blocks: usize,
}

/// One sequence's view of the pool: an ordered block list plus the token
/// count it covers. Handed out at `admit`, grown by `append`, returned at
/// `release` — KV ownership is explicit in the request lifecycle.
#[derive(Debug, Clone)]
pub struct KvLease {
    blocks: Vec<u32>,
    len: usize,
    shared_blocks: usize,
}

impl KvLease {
    /// Logical→physical block mapping (the decode graph's table row).
    pub fn blocks(&self) -> &[u32] {
        &self.blocks
    }

    /// Tokens covered by the lease.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Leading blocks shared with another lease at admission time.
    pub fn shared_blocks(&self) -> usize {
        self.shared_blocks
    }

    pub fn info(&self) -> KvLeaseInfo {
        KvLeaseInfo {
            blocks: self.blocks.len(),
            shared_blocks: self.shared_blocks,
        }
    }
}

/// Pool occupancy snapshot (the `stats` surface of the paged-KV API).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvPoolStats {
    pub block_tokens: usize,
    /// Leasable blocks (excludes the reserved scratch block).
    pub total_blocks: usize,
    pub free_blocks: usize,
    pub active_leases: usize,
    /// Physical blocks currently mapped by more than one lease.
    pub shared_blocks: usize,
    /// Cumulative fresh block allocations.
    pub allocated_blocks: u64,
    /// Cumulative allocations satisfied by sharing an existing block.
    pub shared_hits: u64,
    pub cow_copies: u64,
    /// Cumulative allocation attempts that failed for lack of blocks.
    pub alloc_stalls: u64,
}

impl KvPoolStats {
    /// Fraction of leasable blocks in use.
    pub fn occupancy(&self) -> f64 {
        if self.total_blocks == 0 {
            0.0
        } else {
            1.0 - self.free_blocks as f64 / self.total_blocks as f64
        }
    }

    /// Fraction of block demand served by prefix sharing.
    pub fn share_rate(&self) -> f64 {
        let demand = self.allocated_blocks + self.shared_hits;
        if demand == 0 {
            0.0
        } else {
            self.shared_hits as f64 / demand as f64
        }
    }

    /// Blocks a `tokens`-long sequence maps.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens.max(1))
    }
}

/// The shared, refcounted block pool.
#[derive(Debug, Clone)]
pub struct KvPool {
    block_tokens: usize,
    /// 0 = unbounded (engines without a compiled block-table width).
    max_blocks_per_seq: usize,
    /// Per physical block (index 0 is the reserved scratch block, pinned).
    refcount: Vec<u32>,
    /// Chain hash of the block's content, 0 for private blocks.
    hash_of: Vec<u128>,
    /// Content-addressed index over full, immutable prompt blocks.
    by_hash: HashMap<u128, u32>,
    free: Vec<u32>,
    active_leases: usize,
    allocated_blocks: u64,
    shared_hits: u64,
    cow_copies: u64,
    alloc_stalls: u64,
}

impl KvPool {
    /// A pool of `blocks` leasable blocks of `block_tokens` tokens each.
    /// `max_blocks_per_seq` bounds one lease (0 = unbounded). Physical
    /// ids run `1..=blocks`; id 0 is the reserved scratch block.
    pub fn new(blocks: usize, block_tokens: usize, max_blocks_per_seq: usize) -> KvPool {
        let total = blocks + 1; // + reserved scratch block
        KvPool {
            block_tokens: block_tokens.max(1),
            max_blocks_per_seq,
            refcount: vec![0; total],
            hash_of: vec![0; total],
            by_hash: HashMap::new(),
            // pop() hands out low ids first
            free: (1..total as u32).rev().collect(),
            active_leases: 0,
            allocated_blocks: 0,
            shared_hits: 0,
            cow_copies: 0,
            alloc_stalls: 0,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks a `tokens`-long sequence maps.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// High-watermark admission probe: would taking `needed` more
    /// blocks push the in-use count above `frac` of the leasable
    /// blocks? `frac <= 0` disables the watermark (never above).
    /// Optimistic (evict-and-recompute) admission uses this in place of
    /// worst-case reservation: `needed` is the prompt's block demand —
    /// an upper bound, since prefix sharing may serve part of it for
    /// free — and decode-time growth is left to run to exhaustion,
    /// where the scheduler preempts a victim and recomputes it later.
    pub fn above_watermark(&self, frac: f64, needed: usize) -> bool {
        if frac <= 0.0 {
            return false;
        }
        let total = self.refcount.len() - 1;
        let limit = ((total as f64 * frac.min(1.0)).floor() as usize).max(1);
        let in_use = total - self.free.len();
        in_use + needed > limit
    }

    pub fn stats(&self) -> KvPoolStats {
        KvPoolStats {
            block_tokens: self.block_tokens,
            total_blocks: self.refcount.len() - 1,
            free_blocks: self.free.len(),
            active_leases: self.active_leases,
            shared_blocks: self
                .refcount
                .iter()
                .skip(1)
                .filter(|&&rc| rc > 1)
                .count(),
            allocated_blocks: self.allocated_blocks,
            shared_hits: self.shared_hits,
            cow_copies: self.cow_copies,
            alloc_stalls: self.alloc_stalls,
        }
    }

    /// Position-anchored chain hash: depends on every token id up to and
    /// including this block, so equal hashes mean equal prompt prefixes.
    fn chain_hash(prev: u128, tokens: &[u32]) -> u128 {
        // two independent 64-bit FNV-1a streams → collision-safe enough
        // to content-address blocks without storing the tokens
        let mut lo = (prev as u64) ^ 0xcbf2_9ce4_8422_2325;
        let mut hi = ((prev >> 64) as u64) ^ 0x6c62_272e_07bb_0142;
        for &t in tokens {
            lo = (lo ^ t as u64).wrapping_mul(0x0000_0100_0000_01b3);
            hi = (hi ^ (t as u64).rotate_left(17))
                .wrapping_mul(0x0000_0100_0000_01b3);
            hi ^= hi >> 29;
        }
        ((hi as u128) << 64) | lo as u128 | 1 // never 0 (0 = private)
    }

    fn alloc_block(&mut self) -> Option<u32> {
        let b = self.free.pop()?;
        self.refcount[b as usize] = 1;
        self.hash_of[b as usize] = 0;
        self.allocated_blocks += 1;
        Some(b)
    }

    /// Admit a prompt: map its full blocks (sharing identical prefixes
    /// already in the pool) plus a private partial tail, and publish the
    /// fresh full blocks for future sharing immediately. `reserve` blocks
    /// are kept free for in-flight sequences' growth — admission under
    /// pool pressure fails with [`KvPoolError::Exhausted`] rather than
    /// starving active leases.
    ///
    /// Publication asserts "this block's contents are resident": only
    /// callers that install the prompt before anyone else can admit
    /// (synchronous, single-threaded prefill) may use this entry point.
    /// Chunked admissions lease with [`KvPool::admit_unpublished`] and
    /// [`KvPool::publish`] once the install completes.
    pub fn admit(
        &mut self,
        prompt: &[u32],
        reserve: usize,
    ) -> Result<KvLease, KvPoolError> {
        self.admit_inner(prompt, reserve, true)
    }

    /// [`KvPool::admit`] without publishing the fresh full blocks: they
    /// share *in* an already-published identical prefix (whose contents
    /// are guaranteed resident), but cannot be shared *out* until
    /// [`KvPool::publish`] marks them content-valid. This is the
    /// deferred-admission entry point — a half-installed prompt must
    /// never be shareable.
    pub fn admit_unpublished(
        &mut self,
        prompt: &[u32],
        reserve: usize,
    ) -> Result<KvLease, KvPoolError> {
        self.admit_inner(prompt, reserve, false)
    }

    fn admit_inner(
        &mut self,
        prompt: &[u32],
        reserve: usize,
        publish: bool,
    ) -> Result<KvLease, KvPoolError> {
        let bt = self.block_tokens;
        let n_blocks = self.blocks_for(prompt.len());
        if self.max_blocks_per_seq > 0 && n_blocks > self.max_blocks_per_seq {
            return Err(KvPoolError::WindowExceeded {
                blocks: n_blocks,
                max_blocks: self.max_blocks_per_seq,
            });
        }
        let full = prompt.len() / bt;
        // pass 1: measure the shareable prefix without allocating
        let mut shared = 0usize;
        let mut h: u128 = 0;
        for i in 0..full {
            h = Self::chain_hash(h, &prompt[i * bt..(i + 1) * bt]);
            if shared == i && self.by_hash.contains_key(&h) {
                shared = i + 1;
            }
        }
        let fresh = n_blocks - shared;
        if self.free.len() < fresh + reserve {
            self.alloc_stalls += 1;
            return Err(KvPoolError::Exhausted {
                needed: fresh + reserve,
                free: self.free.len(),
            });
        }
        // pass 2: build the lease
        let mut blocks = Vec::with_capacity(n_blocks);
        let mut h: u128 = 0;
        for i in 0..full {
            h = Self::chain_hash(h, &prompt[i * bt..(i + 1) * bt]);
            if i < shared {
                let b = self.by_hash[&h];
                self.refcount[b as usize] += 1;
                self.shared_hits += 1;
                blocks.push(b);
            } else {
                // pi2-lint: allow(hot-path-unwrap): the free-list size was
                // checked against `fresh + reserve` above and nothing
                // frees or allocates between the check and this pop, so
                // the expect cannot fire; returning Err here instead
                // would leak the partially-built lease's blocks.
                let b = self.alloc_block().expect("free check");
                if publish {
                    self.hash_of[b as usize] = h;
                    self.by_hash.insert(h, b);
                }
                blocks.push(b);
            }
        }
        if prompt.len() % bt != 0 {
            // pi2-lint: allow(hot-path-unwrap): covered by the same
            // free-list check as the full blocks (`fresh` counts the
            // partial tail); an Err path would leak the built prefix.
            let b = self.alloc_block().expect("free check");
            blocks.push(b);
        }
        self.active_leases += 1;
        Ok(KvLease { blocks, len: prompt.len(), shared_blocks: shared })
    }

    /// Publish a lease's full prompt blocks for prefix sharing once
    /// their contents are actually resident. The deferred-admission
    /// counterpart of the publication [`KvPool::admit`] does inline:
    /// call it exactly when the prompt's install completes. Blocks that
    /// are already content-addressed (shared-in prefixes, or a hash some
    /// other lease published first) are left as they are.
    pub fn publish(&mut self, lease: &KvLease, prompt: &[u32]) {
        let bt = self.block_tokens;
        let full = (prompt.len() / bt).min(lease.blocks.len());
        let mut h: u128 = 0;
        for i in 0..full {
            h = Self::chain_hash(h, &prompt[i * bt..(i + 1) * bt]);
            let b = lease.blocks[i];
            if self.hash_of[b as usize] == 0 && !self.by_hash.contains_key(&h)
            {
                self.hash_of[b as usize] = h;
                self.by_hash.insert(h, b);
            }
        }
    }

    /// Extend a lease by one token. Allocates a block at block boundaries
    /// and detaches (copy-on-write) a shared tail before writing into it.
    pub fn append(&mut self, lease: &mut KvLease) -> Result<KvAppend, KvPoolError> {
        let bt = self.block_tokens;
        let pos = lease.len;
        let slot = pos % bt;
        let needed_blocks = pos / bt + 1;
        if self.max_blocks_per_seq > 0 && needed_blocks > self.max_blocks_per_seq
        {
            return Err(KvPoolError::WindowExceeded {
                blocks: needed_blocks,
                max_blocks: self.max_blocks_per_seq,
            });
        }
        let mut cow = None;
        if needed_blocks > lease.blocks.len() {
            let Some(b) = self.alloc_block() else {
                self.alloc_stalls += 1;
                return Err(KvPoolError::Exhausted {
                    needed: 1,
                    free: 0,
                });
            };
            lease.blocks.push(b);
        } else {
            let tail = lease.blocks[needed_blocks - 1];
            if self.refcount[tail as usize] > 1 {
                // copy-on-write: detach from the shared block
                let Some(b) = self.alloc_block() else {
                    self.alloc_stalls += 1;
                    return Err(KvPoolError::Exhausted { needed: 1, free: 0 });
                };
                self.refcount[tail as usize] -= 1;
                self.cow_copies += 1;
                lease.blocks[needed_blocks - 1] = b;
                if lease.shared_blocks >= needed_blocks {
                    lease.shared_blocks = needed_blocks - 1;
                }
                cow = Some(CowCopy { src: tail, dst: b });
            } else if self.hash_of[tail as usize] != 0 {
                // sole owner of a content-indexed block about to mutate:
                // unpublish it so no future admit shares a dirty block
                self.unpublish(tail);
            }
        }
        lease.len = pos + 1;
        let block = lease.blocks[needed_blocks - 1];
        Ok(KvAppend { block, slot, cow })
    }

    /// Undo the most recent [`KvPool::append`] on this lease — the
    /// caller's decode step failed before the position was written, so
    /// the token count shrinks by one and a block allocated at the
    /// boundary goes back to the free list. (A copy-on-write detach is
    /// not reverted: the lease keeps its private copy, which is
    /// semantically identical.)
    pub fn unappend(&mut self, lease: &mut KvLease) {
        if lease.len == 0 {
            return;
        }
        lease.len -= 1;
        let keep = self.blocks_for(lease.len);
        while lease.blocks.len() > keep {
            let Some(b) = lease.blocks.pop() else { break };
            let rc = &mut self.refcount[b as usize];
            debug_assert!(*rc > 0, "unappend of unowned block {b}");
            *rc -= 1;
            if *rc == 0 {
                self.unpublish(b);
                self.free.push(b);
            }
        }
    }

    /// Reservation arithmetic shared by every engine's admission path:
    /// the worst-case blocks a `(prompt, max_tokens)` sequence may reach
    /// (optionally capped by a context window) and the blocks to hold
    /// back when admitting it now — its own decode growth plus every
    /// in-flight sequence's remaining growth, supplied as
    /// `(demand_blocks, held_blocks)` pairs. Returns
    /// `(demand_blocks, reserve_blocks)`.
    pub fn admit_reserve(
        &self,
        prompt_len: usize,
        max_tokens: usize,
        window_tokens: Option<usize>,
        in_flight: impl Iterator<Item = (usize, usize)>,
    ) -> (usize, usize) {
        let mut total =
            prompt_len.saturating_add(max_tokens.saturating_sub(1));
        if let Some(w) = window_tokens {
            total = total.min(w);
        }
        let demand = self.blocks_for(total);
        let growth = demand.saturating_sub(self.blocks_for(prompt_len));
        let remaining: usize =
            in_flight.map(|(d, h)| d.saturating_sub(h)).sum();
        (demand, growth + remaining)
    }

    /// Duplicate a lease, sharing every block (for Best-of-N style
    /// sequence forking). Appends by either copy diverge via CoW.
    pub fn fork(&mut self, lease: &KvLease) -> KvLease {
        for &b in &lease.blocks {
            self.refcount[b as usize] += 1;
            self.shared_hits += 1;
        }
        self.active_leases += 1;
        KvLease {
            blocks: lease.blocks.clone(),
            len: lease.len,
            shared_blocks: lease.blocks.len(),
        }
    }

    /// Return every block of a lease; blocks whose refcount reaches zero
    /// go back on the free list and leave the sharing index.
    pub fn release(&mut self, lease: KvLease) {
        for b in lease.blocks {
            let rc = &mut self.refcount[b as usize];
            debug_assert!(*rc > 0, "double free of block {b}");
            *rc -= 1;
            if *rc == 0 {
                self.unpublish(b);
                self.free.push(b);
            }
        }
        self.active_leases -= 1;
    }

    /// Machine-checkable audit of the pool's entire bookkeeping against
    /// the set of leases currently held by the caller (the pool does not
    /// know its leases — engines own them and pass them in). Checked by
    /// the lifecycle model checker after **every** transition, and by
    /// the churn proptests after every operation:
    ///
    /// - the lease count matches `active_leases`;
    /// - every lease maps exactly `blocks_for(len)` blocks, none of them
    ///   the reserved scratch block or out of range;
    /// - every block's refcount equals the number of leases mapping it
    ///   (so no lease survives a release, and nothing is double-counted);
    /// - the free list is in-range, duplicate-free, disjoint from every
    ///   lease, and complete: `free + leased = total`;
    /// - the prefix-sharing index only maps hashes to live blocks whose
    ///   `hash_of` agrees.
    ///
    /// Failures are typed [`InvariantViolation`]s with the specifics.
    pub fn check_invariants<'a>(
        &self,
        leases: impl IntoIterator<Item = &'a KvLease>,
    ) -> anyhow::Result<()> {
        let total = self.refcount.len();
        let mut counts = vec![0u32; total];
        let mut n_leases = 0usize;
        for lease in leases {
            n_leases += 1;
            if lease.blocks.len() != self.blocks_for(lease.len) {
                return Err(violation(format!(
                    "lease of {} tokens maps {} blocks, expected {}",
                    lease.len,
                    lease.blocks.len(),
                    self.blocks_for(lease.len)
                )));
            }
            if lease.shared_blocks > lease.blocks.len() {
                return Err(violation(format!(
                    "lease claims {} shared blocks but maps only {}",
                    lease.shared_blocks,
                    lease.blocks.len()
                )));
            }
            for &b in &lease.blocks {
                if b == RESERVED_BLOCK {
                    return Err(violation(
                        "a lease maps the reserved scratch block",
                    ));
                }
                if b as usize >= total {
                    return Err(violation(format!(
                        "a lease maps out-of-range block {b} (total {total})"
                    )));
                }
                counts[b as usize] += 1;
            }
        }
        if n_leases != self.active_leases {
            return Err(violation(format!(
                "{} live leases but active_leases = {}",
                n_leases, self.active_leases
            )));
        }
        if self.refcount[RESERVED_BLOCK as usize] != 0 {
            return Err(violation(
                "the reserved scratch block has a nonzero refcount",
            ));
        }
        for b in 1..total {
            if self.refcount[b] != counts[b] {
                return Err(violation(format!(
                    "block {b}: refcount {} but {} leases map it",
                    self.refcount[b], counts[b]
                )));
            }
        }
        let mut on_free = vec![false; total];
        for &b in &self.free {
            if b == RESERVED_BLOCK || b as usize >= total {
                return Err(violation(format!(
                    "free list holds invalid block {b}"
                )));
            }
            if on_free[b as usize] {
                return Err(violation(format!(
                    "block {b} appears twice on the free list"
                )));
            }
            on_free[b as usize] = true;
            if self.refcount[b as usize] != 0 {
                return Err(violation(format!(
                    "free block {b} has refcount {}",
                    self.refcount[b as usize]
                )));
            }
        }
        let leased = (1..total).filter(|&b| counts[b] > 0).count();
        if self.free.len() + leased != total - 1 {
            return Err(violation(format!(
                "block leak: {} free + {} leased != {} total",
                self.free.len(),
                leased,
                total - 1
            )));
        }
        for (&h, &b) in &self.by_hash {
            if b as usize >= total || self.hash_of[b as usize] != h {
                return Err(violation(format!(
                    "sharing index maps a hash to block {b} whose hash \
                     disagrees"
                )));
            }
            if self.refcount[b as usize] == 0 {
                return Err(violation(format!(
                    "sharing index maps a hash to freed block {b}"
                )));
            }
        }
        Ok(())
    }

    fn unpublish(&mut self, block: u32) {
        let h = self.hash_of[block as usize];
        if h != 0 {
            if self.by_hash.get(&h) == Some(&block) {
                self.by_hash.remove(&h);
            }
            self.hash_of[block as usize] = 0;
        }
    }
}

/// Convert a pool failure into `anyhow` while keeping the typed error
/// downcastable (what [`crate::coordinator::Coordinator`] keys on).
pub fn pool_err(e: KvPoolError) -> anyhow::Error {
    anyhow::Error::new(e)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(lease: &KvLease) -> Vec<u32> {
        lease.blocks().to_vec()
    }

    #[test]
    fn admit_maps_blocks_and_release_frees_them() {
        let mut p = KvPool::new(8, 4, 0);
        assert_eq!(p.free_blocks(), 8);
        let lease = p.admit(&[1, 2, 3, 4, 5], 0).unwrap(); // 2 blocks
        assert_eq!(lease.len(), 5);
        assert_eq!(lease.blocks().len(), 2);
        assert_eq!(lease.shared_blocks(), 0);
        assert!(lease.blocks().iter().all(|&b| b != RESERVED_BLOCK));
        assert_eq!(p.free_blocks(), 6);
        p.release(lease);
        assert_eq!(p.free_blocks(), 8);
        assert_eq!(p.stats().active_leases, 0);
    }

    #[test]
    fn append_allocates_at_block_boundaries_only() {
        let mut p = KvPool::new(8, 4, 0);
        let mut lease = p.admit(&[9, 9, 9], 0).unwrap(); // 3 of 4 slots
        assert_eq!(p.free_blocks(), 7);
        let a = p.append(&mut lease).unwrap(); // fills the tail block
        assert_eq!(a.slot, 3);
        assert_eq!(p.free_blocks(), 7);
        let a = p.append(&mut lease).unwrap(); // crosses the boundary
        assert_eq!(a.slot, 0);
        assert_eq!(lease.blocks().len(), 2);
        assert_eq!(p.free_blocks(), 6);
        assert_eq!(lease.len(), 5);
        p.release(lease);
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn identical_prompt_prefixes_share_blocks() {
        let mut p = KvPool::new(16, 4, 0);
        let prompt = [7u32, 1, 2, 3, 4, 5, 6, 7, 9, 9]; // 2 full + partial
        let a = p.admit(&prompt, 0).unwrap();
        let used_solo = 16 - p.free_blocks();
        let b = p.admit(&prompt, 0).unwrap();
        // the two full prompt blocks are shared; only the partial tail is
        // private, so the second admission costs 1 block instead of 3
        assert_eq!(b.shared_blocks(), 2);
        assert_eq!(&ids(&b)[..2], &ids(&a)[..2]);
        assert_ne!(ids(&b)[2], ids(&a)[2]);
        assert_eq!(16 - p.free_blocks(), used_solo + 1);
        let st = p.stats();
        assert_eq!(st.shared_hits, 2);
        assert_eq!(st.shared_blocks, 2);
        assert!(st.share_rate() > 0.0);
        // divergent prompt shares nothing
        let c = p.admit(&[8, 8, 8, 8, 4, 5, 6, 7], 0).unwrap();
        assert_eq!(c.shared_blocks(), 0);
        p.release(a);
        p.release(b);
        p.release(c);
        assert_eq!(p.free_blocks(), 16);
    }

    #[test]
    fn shared_blocks_survive_one_release_and_free_on_last() {
        let mut p = KvPool::new(8, 2, 0);
        let prompt = [1u32, 2, 3, 4];
        let a = p.admit(&prompt, 0).unwrap();
        let b = p.admit(&prompt, 0).unwrap();
        assert_eq!(ids(&a), ids(&b));
        p.release(a);
        assert_eq!(p.free_blocks(), 6, "blocks freed while still leased");
        // the prefix is still published: a third admit re-shares it
        let c = p.admit(&prompt, 0).unwrap();
        assert_eq!(c.shared_blocks(), 2);
        p.release(b);
        p.release(c);
        assert_eq!(p.free_blocks(), 8);
        // fully released prefix is unpublished: next admit allocates fresh
        let d = p.admit(&prompt, 0).unwrap();
        assert_eq!(d.shared_blocks(), 0);
        p.release(d);
    }

    #[test]
    fn fork_shares_everything_and_append_copies_on_write() {
        let mut p = KvPool::new(8, 4, 0);
        let mut a = p.admit(&[1, 2, 3], 0).unwrap(); // 1 partial block
        let mut b = p.fork(&a);
        assert_eq!(ids(&a), ids(&b));
        assert_eq!(p.stats().shared_blocks, 1);
        // first append on the fork detaches its tail
        let app = p.append(&mut b).unwrap();
        let cow = app.cow.expect("shared tail must copy on write");
        assert_eq!(cow.src, ids(&a)[0]);
        assert_eq!(cow.dst, ids(&b)[0]);
        assert_ne!(ids(&a)[0], ids(&b)[0]);
        assert_eq!(p.stats().cow_copies, 1);
        // the original, now sole owner, appends in place
        let app = p.append(&mut a).unwrap();
        assert!(app.cow.is_none());
        p.release(a);
        p.release(b);
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn unappend_reverts_len_and_boundary_allocations() {
        let mut p = KvPool::new(8, 4, 0);
        let mut lease = p.admit(&[1, 2, 3, 4], 0).unwrap(); // 1 full block
        let free0 = p.free_blocks();
        // boundary append allocates a block; unappend returns it
        p.append(&mut lease).unwrap();
        assert_eq!(p.free_blocks(), free0 - 1);
        p.unappend(&mut lease);
        assert_eq!(lease.len(), 4);
        assert_eq!(lease.blocks().len(), 1);
        assert_eq!(p.free_blocks(), free0);
        // mid-block append allocates nothing; unappend frees nothing
        p.append(&mut lease).unwrap(); // pos 4 → new block
        p.append(&mut lease).unwrap(); // pos 5, same block
        let free1 = p.free_blocks();
        p.unappend(&mut lease);
        assert_eq!(lease.len(), 5);
        assert_eq!(p.free_blocks(), free1);
        p.release(lease);
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn unpublished_admission_shares_in_but_not_out() {
        let mut p = KvPool::new(16, 4, 0);
        let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8]; // 2 full blocks
        // a half-installed prompt must not be shareable: before publish,
        // an identical admission allocates fresh blocks
        let a = p.admit_unpublished(&prompt, 0).unwrap();
        let b = p.admit_unpublished(&prompt, 0).unwrap();
        assert_eq!(b.shared_blocks(), 0, "shared an unpublished block");
        assert_ne!(ids(&a)[0], ids(&b)[0]);
        // once a's install completes and publishes, new admissions share
        p.publish(&a, &prompt);
        let c = p.admit_unpublished(&prompt, 0).unwrap();
        assert_eq!(c.shared_blocks(), 2);
        assert_eq!(&ids(&c)[..2], &ids(&a)[..2]);
        // publishing b afterwards is a no-op: the hashes are taken
        p.publish(&b, &prompt);
        let d = p.admit_unpublished(&prompt, 0).unwrap();
        assert_eq!(&ids(&d)[..2], &ids(&a)[..2]);
        p.release(a);
        p.release(b);
        p.release(c);
        p.release(d);
        assert_eq!(p.free_blocks(), 16);
    }

    #[test]
    fn unpublished_release_leaves_no_stale_index() {
        // an unpublished lease released mid-install must leave the
        // sharing index untouched (its blocks were never in it)
        let mut p = KvPool::new(8, 4, 0);
        let prompt = [9u32, 9, 9, 9];
        let a = p.admit_unpublished(&prompt, 0).unwrap();
        p.release(a);
        assert_eq!(p.free_blocks(), 8);
        let b = p.admit_unpublished(&prompt, 0).unwrap();
        assert_eq!(b.shared_blocks(), 0);
        p.release(b);
    }

    #[test]
    fn admit_reserve_math() {
        let p = KvPool::new(32, 4, 0);
        // prompt 5 → 2 blocks; total 5+7 = 12 → 3 blocks; growth 1
        let (demand, reserve) = p.admit_reserve(5, 8, None, std::iter::empty());
        assert_eq!((demand, reserve), (3, 1));
        // a window caps the demand
        let (demand, _) = p.admit_reserve(5, 100, Some(16), std::iter::empty());
        assert_eq!(demand, 4);
        // in-flight remaining growth adds to the reserve
        let in_flight = [(3usize, 1usize), (4, 4)].into_iter();
        let (_, reserve) = p.admit_reserve(5, 8, None, in_flight);
        assert_eq!(reserve, 1 + 2);
    }

    #[test]
    fn exhaustion_is_typed_and_counts_stalls() {
        let mut p = KvPool::new(2, 4, 0);
        let a = p.admit(&[1, 2, 3, 4, 5], 0).unwrap(); // 2 blocks
        let err = p.admit(&[9], 0).unwrap_err();
        assert_eq!(err, KvPoolError::Exhausted { needed: 1, free: 0 });
        assert_eq!(p.stats().alloc_stalls, 1);
        p.release(a);
        assert!(p.admit(&[9], 0).is_ok());
    }

    #[test]
    fn reserve_holds_back_blocks_for_growth() {
        let mut p = KvPool::new(3, 4, 0);
        let mut a = p.admit(&[1, 2, 3, 4], 0).unwrap();
        // 2 blocks free, but a 1-block admit with reserve 2 must fail
        let err = p.admit(&[5], 2).unwrap_err();
        assert_eq!(err, KvPoolError::Exhausted { needed: 3, free: 2 });
        assert!(p.admit(&[5], 1).is_ok());
        // the reserve kept a block for the in-flight lease's growth
        assert!(p.append(&mut a).is_ok());
    }

    #[test]
    fn window_bound_rejects_oversized_sequences() {
        let mut p = KvPool::new(16, 4, 2);
        assert_eq!(
            p.admit(&[0; 9], 0).unwrap_err(),
            KvPoolError::WindowExceeded { blocks: 3, max_blocks: 2 }
        );
        let mut lease = p.admit(&[0; 8], 0).unwrap();
        assert_eq!(
            p.append(&mut lease).unwrap_err(),
            KvPoolError::WindowExceeded { blocks: 3, max_blocks: 2 }
        );
        p.release(lease);
    }

    #[test]
    fn append_past_published_block_keeps_it_shareable() {
        let mut p = KvPool::new(8, 4, 0);
        // prompt is exactly one full block → published for sharing
        let mut a = p.admit(&[1, 2, 3, 4], 0).unwrap();
        // append crosses into a new block; the full block stays published
        p.append(&mut a).unwrap();
        let b = p.admit(&[1, 2, 3, 4], 0).unwrap();
        assert_eq!(b.shared_blocks(), 1);
        p.release(a);
        p.release(b);
    }

    #[test]
    fn churn_maintains_refcount_and_free_list_invariants() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(7);
        let mut p = KvPool::new(32, 4, 0);
        let mut live: Vec<KvLease> = Vec::new();
        for step in 0..5000 {
            match rng.below(4) {
                0 => {
                    let len = 1 + rng.below(10);
                    let prompt: Vec<u32> =
                        (0..len).map(|_| rng.below(4) as u32).collect();
                    if let Ok(l) = p.admit(&prompt, 0) {
                        live.push(l);
                    }
                }
                1 if !live.is_empty() => {
                    let i = rng.below(live.len());
                    let _ = p.append(&mut live[i]);
                }
                2 if !live.is_empty() => {
                    let i = rng.below(live.len());
                    let l = live.swap_remove(i);
                    p.release(l);
                }
                _ if !live.is_empty() => {
                    let i = rng.below(live.len());
                    let f = p.fork(&live[i]);
                    live.push(f);
                }
                _ => {}
            }
            // the full machine-checkable invariant set after EVERY
            // operation: refcount == lease-membership count, free list
            // disjoint/duplicate-free/complete, lease shapes coherent,
            // sharing index live — the same audit the model checker
            // asserts after every lifecycle transition
            if let Err(e) = p.check_invariants(&live) {
                panic!("step {step}: {e}");
            }
            assert_eq!(p.stats().active_leases, live.len());
        }
        for l in live {
            p.release(l);
        }
        assert_eq!(p.free_blocks(), 32);
        assert!(p.stats().allocated_blocks > 0);
    }

    #[test]
    fn check_invariants_passes_clean_and_catches_a_leaked_lease() {
        let mut p = KvPool::new(8, 4, 0);
        let a = p.admit(&[1, 2, 3, 4, 5], 0).unwrap();
        let b = p.admit(&[9, 9], 0).unwrap();
        p.check_invariants([&a, &b]).unwrap();
        // a lease dropped without release (the planted-bug class the
        // model checker hunts): its blocks keep nonzero refcounts off
        // the free list, and the audit reports a typed violation
        drop(b);
        let err = p.check_invariants([&a]).unwrap_err();
        assert!(err.downcast_ref::<InvariantViolation>().is_some(), "{err}");
        assert!(err.to_string().contains("active_leases"), "{err}");
        p.release(a);
    }

    #[test]
    fn stats_snapshot_math() {
        let s = KvPoolStats {
            block_tokens: 4,
            total_blocks: 10,
            free_blocks: 4,
            allocated_blocks: 6,
            shared_hits: 2,
            ..Default::default()
        };
        assert!((s.occupancy() - 0.6).abs() < 1e-12);
        assert!((s.share_rate() - 0.25).abs() < 1e-12);
        assert_eq!(s.blocks_for(9), 3);
        assert_eq!(KvPoolStats::default().occupancy(), 0.0);
        assert_eq!(KvPoolStats::default().share_rate(), 0.0);
    }

    #[test]
    fn pool_error_displays_and_downcasts() {
        let e = pool_err(KvPoolError::Exhausted { needed: 3, free: 1 });
        assert!(e.to_string().contains("exhausted"));
        assert!(e.downcast_ref::<KvPoolError>().is_some());
    }
}
