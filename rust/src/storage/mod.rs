//! Storage substrate: a calibrated UFS timing model + a real-file backend.
//!
//! The paper's testbed storage (UFS 4.0 / 3.1) does not exist on this
//! machine, so experiments run against [`UfsModel`], a timing model that
//! encodes all four measured characteristics from §2.3.2:
//!
//!   1. block-size-dependent bandwidth (450MB/s @4KB → 4GB/s @512KB seq),
//!   2. data-range sensitivity of random reads (Fig.3-b),
//!   3. issuing-core dependency (Table 1: big > mid > little),
//!   4. single-command-queue contention (up to −40% with multiple issuers).
//!
//! The end-to-end example instead uses [`FlashFile`], a real pread-based
//! backend over the bundle-layout weight file, optionally wrapped in
//! [`ThrottledFile`] which injects UFS-model latencies so a laptop NVMe
//! device behaves like phone flash.

pub mod fault;
pub mod flash_file;

pub use fault::{
    Clock, FaultCounts, FaultDecision, FaultInjector, FaultSite, FaultSpec,
    InjectedFault, IoDeadlineExceeded, RetryPolicy, SystemClock, VirtualClock,
};
pub use flash_file::{
    FlashFile, FlashReadError, FlashReadErrorKind, ThrottledFile,
};

use crate::config::{CoreClass, UfsConfig};

/// Access pattern of a read burst.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoPattern {
    Sequential,
    /// Random reads scattered over `range_bytes` of the backing store.
    Random,
}

/// One modeled I/O burst: `count` reads of `block_bytes` each.
#[derive(Debug, Clone, Copy)]
pub struct IoBurst {
    pub pattern: IoPattern,
    pub block_bytes: u64,
    pub count: u64,
    /// Locality range the random offsets are drawn from (ignored for
    /// sequential reads).
    pub range_bytes: u64,
    /// CPU core class driving the UFS command queue.
    pub core: CoreClass,
    /// Number of threads concurrently issuing (1 = no contention).
    pub issuers: usize,
}

impl IoBurst {
    pub fn total_bytes(&self) -> u64 {
        self.block_bytes * self.count
    }
}

/// Calibrated UFS timing model.
#[derive(Debug, Clone)]
pub struct UfsModel {
    cfg: UfsConfig,
}

impl UfsModel {
    pub fn new(cfg: UfsConfig) -> Self {
        UfsModel { cfg }
    }

    pub fn config(&self) -> &UfsConfig {
        &self.cfg
    }

    /// Effective throughput (MB/s) for a burst.
    pub fn bandwidth_mbps(&self, burst: &IoBurst) -> f64 {
        let base = match burst.pattern {
            IoPattern::Sequential => interp_log(&self.cfg.seq_curve, burst.block_bytes),
            IoPattern::Random => {
                let raw = interp_log(&self.cfg.rand_curve, burst.block_bytes);
                raw * interp_log(&self.cfg.range_factor, burst.range_bytes)
            }
        };
        let core = match burst.core {
            CoreClass::Big => self.cfg.core_factor_big,
            CoreClass::Mid => self.cfg.core_factor_mid,
            CoreClass::Little => self.cfg.core_factor_little,
        };
        // Single command queue: extra issuers only contend (§2.3.2).
        let contention = if burst.issuers <= 1 {
            1.0
        } else {
            let extra = (burst.issuers - 1).min(3) as f64 / 3.0;
            1.0 - self.cfg.multi_queue_penalty * extra
        };
        base * core * contention
    }

    /// Time (seconds) to complete a burst on the modeled device.
    pub fn burst_time_s(&self, burst: &IoBurst) -> f64 {
        if burst.count == 0 {
            return 0.0;
        }
        let bw = self.bandwidth_mbps(burst) * 1e6; // bytes/s
        let transfer = burst.total_bytes() as f64 / bw;
        // Per-command latency floor matters for small scattered reads but
        // is pipelined away for long sequential streams.
        let cmd_floor = match burst.pattern {
            IoPattern::Sequential => 0.0,
            IoPattern::Random => {
                burst.count as f64 * self.cfg.cmd_latency_us * 1e-6 * 0.02
            }
        };
        transfer + cmd_floor
    }

    /// Time for one read of `block_bytes` (convenience).
    pub fn single_read_s(
        &self,
        pattern: IoPattern,
        block_bytes: u64,
        range_bytes: u64,
        core: CoreClass,
    ) -> f64 {
        self.burst_time_s(&IoBurst {
            pattern,
            block_bytes,
            count: 1,
            range_bytes,
            core,
            issuers: 1,
        })
    }
}

/// Log-log interpolation over (x, y) anchors, clamped at the ends.
fn interp_log(anchors: &[(u64, f64)], x: u64) -> f64 {
    debug_assert!(!anchors.is_empty());
    if x <= anchors[0].0 {
        return anchors[0].1;
    }
    if x >= anchors[anchors.len() - 1].0 {
        return anchors[anchors.len() - 1].1;
    }
    for w in anchors.windows(2) {
        let (x0, y0) = w[0];
        let (x1, y1) = w[1];
        if x >= x0 && x <= x1 {
            let lx0 = (x0 as f64).ln();
            let lx1 = (x1 as f64).ln();
            let t = ((x as f64).ln() - lx0) / (lx1 - lx0);
            return y0 * (y1 / y0).powf(t);
        }
    }
    anchors[anchors.len() - 1].1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::oneplus_12;

    const KB: u64 = 1024;
    const MB: u64 = 1024 * 1024;

    fn model() -> UfsModel {
        UfsModel::new(oneplus_12().ufs)
    }

    fn burst(pattern: IoPattern, block: u64, range: u64, core: CoreClass) -> IoBurst {
        IoBurst { pattern, block_bytes: block, count: 1000, range_bytes: range, core, issuers: 1 }
    }

    #[test]
    fn sequential_bandwidth_matches_2_3_2() {
        let m = model();
        let b4 = m.bandwidth_mbps(&burst(IoPattern::Sequential, 4 * KB, 0, CoreClass::Big));
        let b512 = m.bandwidth_mbps(&burst(IoPattern::Sequential, 512 * KB, 0, CoreClass::Big));
        assert!((b4 - 450.0).abs() < 1.0, "{b4}");
        assert!((b512 - 4000.0).abs() < 1.0, "{b512}");
    }

    #[test]
    fn random_4k_matches_fig3b() {
        let m = model();
        // 4KB within 128MB ≈ 1GB/s; over 512MB < 850MB/s (Fig.3-b).
        let near = m.bandwidth_mbps(&burst(IoPattern::Random, 4 * KB, 128 * MB, CoreClass::Big));
        let far = m.bandwidth_mbps(&burst(IoPattern::Random, 4 * KB, 512 * MB, CoreClass::Big));
        assert!((near - 1076.0).abs() < 5.0, "{near}");
        assert!(far < 860.0 && far > 700.0, "{far}");
    }

    #[test]
    fn core_hierarchy_matches_table1() {
        let m = model();
        let mk = |c| m.bandwidth_mbps(&burst(IoPattern::Random, 4 * KB, 128 * MB, c));
        let (big, mid, little) = (mk(CoreClass::Big), mk(CoreClass::Mid), mk(CoreClass::Little));
        assert!(big > mid && mid > little);
        assert!((little / big - 761.87 / 1076.10).abs() < 1e-6);
    }

    #[test]
    fn multi_issuer_contention_degrades_up_to_40pct() {
        let m = model();
        let one = m.bandwidth_mbps(&IoBurst { issuers: 1, ..burst(IoPattern::Random, 4 * KB, 128 * MB, CoreClass::Big) });
        let four = m.bandwidth_mbps(&IoBurst { issuers: 4, ..burst(IoPattern::Random, 4 * KB, 128 * MB, CoreClass::Big) });
        let eight = m.bandwidth_mbps(&IoBurst { issuers: 8, ..burst(IoPattern::Random, 4 * KB, 128 * MB, CoreClass::Big) });
        assert!((four / one - 0.6).abs() < 1e-9, "{}", four / one);
        // penalty saturates at 40%
        assert!((eight / one - 0.6).abs() < 1e-9);
    }

    #[test]
    fn seq_beats_random_at_same_block() {
        // §7.2.2: sequential layer loads are ~3× faster than random.
        let m = model();
        let seq = m.bandwidth_mbps(&burst(IoPattern::Sequential, 256 * KB, 0, CoreClass::Big));
        let rand = m.bandwidth_mbps(&burst(IoPattern::Random, 8 * KB, 4096 * MB, CoreClass::Big));
        assert!(seq / rand > 2.5, "seq/rand = {}", seq / rand);
    }

    #[test]
    fn two_4k_reads_beat_one_8k_read() {
        // §4.4: PowerInfer-2 splits an 8KB bundle into two 4KB reads
        // because measured 4KB throughput × 2 exceeds one 8KB op. The
        // calibrated curves must preserve that ordering per *byte moved*:
        // bandwidth(4KB)·2 issued back-to-back vs bandwidth(8KB).
        let m = model();
        let t_two_4k = m.burst_time_s(&IoBurst {
            pattern: IoPattern::Random, block_bytes: 4 * KB, count: 2,
            range_bytes: 128 * MB, core: CoreClass::Big, issuers: 1,
        });
        let t_one_8k = m.burst_time_s(&IoBurst {
            pattern: IoPattern::Random, block_bytes: 8 * KB, count: 1,
            range_bytes: 128 * MB, core: CoreClass::Big, issuers: 1,
        });
        // two-phase loading only fetches the second 4KB ~80% of the time;
        // expected bytes 4KB + 0.8·4KB must be cheaper than a flat 8KB.
        let t_expected_two_phase = t_two_4k / 2.0 * 1.8;
        assert!(t_expected_two_phase < t_one_8k,
                "two-phase {t_expected_two_phase} vs 8k {t_one_8k}");
    }

    #[test]
    fn burst_time_scales_linearly_in_count() {
        let m = model();
        let t1 = m.burst_time_s(&IoBurst { count: 100, ..burst(IoPattern::Random, 4 * KB, 128 * MB, CoreClass::Big) });
        let t2 = m.burst_time_s(&IoBurst { count: 200, ..burst(IoPattern::Random, 4 * KB, 128 * MB, CoreClass::Big) });
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_count_burst_is_free() {
        let m = model();
        assert_eq!(m.burst_time_s(&IoBurst { count: 0, ..burst(IoPattern::Random, 4 * KB, 128 * MB, CoreClass::Big) }), 0.0);
    }

    #[test]
    fn interp_is_monotone_between_anchors() {
        let m = model();
        let mut prev = 0.0;
        for kb in [4u64, 8, 16, 32, 64, 128, 256, 512] {
            let bw = m.bandwidth_mbps(&burst(IoPattern::Sequential, kb * KB, 0, CoreClass::Big));
            assert!(bw > prev, "bw({kb}KB) = {bw} ≤ {prev}");
            prev = bw;
        }
    }
}
