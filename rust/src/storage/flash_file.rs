//! Real-file storage backend for the end-to-end example.
//!
//! `FlashFile` does positioned reads (pread) against the bundle-layout
//! weight file produced by `model::weights`. `ThrottledFile` wraps it and
//! sleeps the difference between real NVMe latency and the UFS model's
//! predicted latency, so the e2e example experiences phone-like storage.

use std::fs::File;
use std::os::unix::io::AsRawFd;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Error, Result};

use crate::config::CoreClass;
use crate::storage::fault::{
    Clock, FaultDecision, FaultInjector, FaultSite, InjectedFault, SystemClock,
};
use crate::storage::{IoPattern, UfsModel};

/// What went wrong with a positioned read.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlashReadErrorKind {
    /// The requested range extends past the end of the file.
    OutOfRange,
    /// `pread` returned 0 or -1 before the full range was read.
    ShortRead,
}

/// Typed positioned-read failure: callers on the offload path (and the
/// lint's typed-error discipline) need the exact failing range, not a
/// formatted string — a `ShortRead` at a cluster-record offset means a
/// truncated/corrupt store, an `OutOfRange` a caller arithmetic bug.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlashReadError {
    pub kind: FlashReadErrorKind,
    /// Byte offset the failing read started at (for `ShortRead`, the
    /// first byte that could not be read).
    pub offset: u64,
    /// Bytes still requested at `offset`.
    pub len: usize,
    /// Total file length the range was checked against.
    pub file_len: u64,
}

impl std::fmt::Display for FlashReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.kind {
            FlashReadErrorKind::OutOfRange => write!(
                f,
                "read past EOF: offset {} + {} bytes > file length {}",
                self.offset, self.len, self.file_len
            ),
            FlashReadErrorKind::ShortRead => write!(
                f,
                "pread failed or hit EOF at offset {} ({} bytes still \
                 unread of a {}-byte file)",
                self.offset, self.len, self.file_len
            ),
        }
    }
}

impl std::error::Error for FlashReadError {}

/// Positioned-read file handle (thread-safe: pread carries its own offset).
#[derive(Debug)]
pub struct FlashFile {
    file: File,
    len: u64,
}

impl FlashFile {
    pub fn open(path: &Path) -> Result<Self> {
        let file = File::open(path)
            .with_context(|| format!("open flash file {}", path.display()))?;
        let len = file.metadata()?.len();
        Ok(FlashFile { file, len })
    }

    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read exactly `buf.len()` bytes at `offset`.
    ///
    /// The crate-wide `#![deny(unsafe_code)]` is lifted for this one
    /// function (the single allowlisted site, enforced again textually
    /// by `pi2 check`): positioned reads need `libc::pread` — the
    /// stable-std alternative takes `&mut self` or the raw fd anyway —
    /// and the call is sound because `buf` is a live exclusive slice
    /// whose length bounds every byte `pread` may write.
    #[allow(unsafe_code)]
    pub fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        if offset + buf.len() as u64 > self.len {
            return Err(Error::new(FlashReadError {
                kind: FlashReadErrorKind::OutOfRange,
                offset,
                len: buf.len(),
                file_len: self.len,
            }));
        }
        let mut done = 0usize;
        while done < buf.len() {
            let n = unsafe {
                libc::pread(
                    self.file.as_raw_fd(),
                    buf[done..].as_mut_ptr() as *mut libc::c_void,
                    buf.len() - done,
                    (offset + done as u64) as libc::off_t,
                )
            };
            if n <= 0 {
                return Err(Error::new(FlashReadError {
                    kind: FlashReadErrorKind::ShortRead,
                    offset: offset + done as u64,
                    len: buf.len() - done,
                    file_len: self.len,
                }));
            }
            done += n as usize;
        }
        Ok(())
    }

    /// Read `len` bytes at `offset` as f32s (offset/len in bytes; len must
    /// be a multiple of 4).
    pub fn read_f32s(&self, offset: u64, count: usize) -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; count * 4];
        self.read_at(offset, &mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// UFS-latency-injecting wrapper: every read takes at least what the UFS
/// model says it would take on the phone. Carries the fault domain: an
/// optional seeded [`FaultInjector`] decides each read's fate, and every
/// delay (modeled latency, injected spikes) routes through the
/// injectable [`Clock`] so tests can run the whole path virtually.
#[derive(Debug)]
pub struct ThrottledFile {
    inner: FlashFile,
    model: UfsModel,
    core: CoreClass,
    clock: Arc<dyn Clock>,
    injector: Option<Arc<FaultInjector>>,
    /// Which injector site this handle's reads draw from (the weight
    /// file reads as [`FaultSite::FlashRead`]; `NeuronStore` retags its
    /// handle [`FaultSite::ClusterRead`]).
    site: FaultSite,
    /// Set false to disable throttling (raw NVMe speed).
    pub throttle: bool,
}

impl ThrottledFile {
    pub fn new(inner: FlashFile, model: UfsModel, core: CoreClass) -> Self {
        ThrottledFile {
            inner,
            model,
            core,
            clock: Arc::new(SystemClock::new()),
            injector: None,
            site: FaultSite::FlashRead,
            throttle: true,
        }
    }

    /// Retag which injector site this handle's reads draw from.
    pub fn set_fault_site(&mut self, site: FaultSite) {
        self.site = site;
    }

    pub fn len(&self) -> u64 {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Swap the time source (tests/checker install a `VirtualClock`).
    pub fn set_clock(&mut self, clock: Arc<dyn Clock>) {
        self.clock = clock;
    }

    pub fn clock(&self) -> Arc<dyn Clock> {
        Arc::clone(&self.clock)
    }

    /// Arm (or disarm) fault injection on this handle's reads.
    pub fn set_injector(&mut self, injector: Option<Arc<FaultInjector>>) {
        self.injector = injector;
    }

    pub fn injector(&self) -> Option<Arc<FaultInjector>> {
        self.injector.clone()
    }

    /// Random-pattern positioned read with injected UFS latency and,
    /// when an injector is armed, programmable faults: transient errors
    /// surface as a downcastable [`InjectedFault`], torn reads deliver a
    /// zeroed tail (record checksums exist to catch this), and latency
    /// spikes / stuck reads sleep through the clock.
    pub fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        let start = Instant::now();
        let mut keep = buf.len();
        if let Some(inj) = &self.injector {
            match inj.decide(self.site) {
                Some(FaultDecision::Transient) => {
                    return Err(Error::new(InjectedFault {
                        site: self.site,
                        offset,
                    }));
                }
                Some(FaultDecision::ShortRead { keep_frac }) => {
                    keep = ((buf.len() as f64 * keep_frac) as usize)
                        .min(buf.len());
                }
                Some(FaultDecision::Delay { delay_s, .. }) => {
                    self.clock.sleep(Duration::from_secs_f64(delay_s));
                }
                None => {}
            }
        }
        self.inner.read_at(offset, &mut buf[..keep])?;
        if keep < buf.len() {
            // torn read: the tail never landed — zero it so stale buffer
            // contents cannot masquerade as weights
            buf[keep..].fill(0);
        }
        if self.throttle {
            let modeled = self.model.single_read_s(
                IoPattern::Random,
                buf.len() as u64,
                self.inner.len(),
                self.core,
            );
            let elapsed = start.elapsed().as_secs_f64();
            if modeled > elapsed {
                self.clock.sleep(Duration::from_secs_f64(modeled - elapsed));
            }
        }
        Ok(())
    }

    pub fn read_f32s(&self, offset: u64, count: usize) -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; count * 4];
        self.read_at(offset, &mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::oneplus_12;
    use std::io::Write;

    fn tmpfile(data: &[u8]) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(format!(
            "pi2_flash_test_{}_{}",
            std::process::id(),
            data.len()
        ));
        let mut f = File::create(&path).unwrap();
        f.write_all(data).unwrap();
        path
    }

    #[test]
    fn read_at_roundtrip() {
        let data: Vec<u8> = (0..=255).collect();
        let path = tmpfile(&data);
        let f = FlashFile::open(&path).unwrap();
        assert_eq!(f.len(), 256);
        let mut buf = [0u8; 16];
        f.read_at(100, &mut buf).unwrap();
        assert_eq!(&buf[..], &data[100..116]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn read_f32s_decodes_le() {
        let values = [1.5f32, -2.25, 3.0];
        let bytes: Vec<u8> = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        let path = tmpfile(&bytes);
        let f = FlashFile::open(&path).unwrap();
        assert_eq!(f.read_f32s(4, 2).unwrap(), vec![-2.25, 3.0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn read_past_eof_errors_are_typed_with_range_context() {
        let path = tmpfile(&[0u8; 8]);
        let f = FlashFile::open(&path).unwrap();
        let mut buf = [0u8; 16];
        let err = f.read_at(0, &mut buf).unwrap_err();
        let fre = err.downcast_ref::<FlashReadError>().unwrap();
        assert_eq!(fre.kind, FlashReadErrorKind::OutOfRange);
        assert_eq!((fre.offset, fre.len, fre.file_len), (0, 16, 8));
        let err = f.read_at(9, &mut buf[..1]).unwrap_err();
        let fre = err.downcast_ref::<FlashReadError>().unwrap();
        assert_eq!(fre.kind, FlashReadErrorKind::OutOfRange);
        assert_eq!((fre.offset, fre.len, fre.file_len), (9, 1, 8));
        // the message still carries the range for humans
        assert!(format!("{fre}").contains("offset 9"), "{fre}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn throttled_read_is_slower_than_model_floor() {
        let data = vec![7u8; 64 * 1024];
        let path = tmpfile(&data);
        let model = UfsModel::new(oneplus_12().ufs);
        let modeled = model.single_read_s(
            IoPattern::Random, 4096, 64 * 1024, CoreClass::Big);
        let t = ThrottledFile::new(
            FlashFile::open(&path).unwrap(), model, CoreClass::Big);
        let start = Instant::now();
        let mut buf = [0u8; 4096];
        t.read_at(0, &mut buf).unwrap();
        assert!(start.elapsed().as_secs_f64() >= modeled * 0.9);
        assert!(buf.iter().all(|&b| b == 7));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn injected_faults_are_typed_and_torn_reads_zero_the_tail() {
        use crate::storage::fault::{
            FaultInjector, FaultSite, FaultSpec, InjectedFault, VirtualClock,
        };
        use std::sync::Arc;
        let data = vec![9u8; 4096];
        let path = tmpfile(&data);
        let mut t = ThrottledFile::new(
            FlashFile::open(&path).unwrap(),
            UfsModel::new(oneplus_12().ufs),
            CoreClass::Big,
        );
        t.throttle = false;
        t.set_clock(Arc::new(VirtualClock::new()));
        let inj = Arc::new(FaultInjector::new(11));
        inj.set(FaultSite::FlashRead, FaultSpec::transient(1.0));
        t.set_injector(Some(Arc::clone(&inj)));
        let mut buf = [0u8; 64];
        let err = t.read_at(0, &mut buf).unwrap_err();
        assert!(err.downcast_ref::<InjectedFault>().is_some(), "{err:#}");
        // torn reads deliver a prefix and a zeroed (not stale) tail
        inj.set(
            FaultSite::FlashRead,
            FaultSpec { short_read_rate: 1.0, ..FaultSpec::default() },
        );
        let mut buf = [0xAAu8; 64];
        t.read_at(0, &mut buf).unwrap();
        assert_eq!(buf[0], 9, "the delivered prefix is real data");
        assert_eq!(*buf.last().unwrap(), 0, "the torn tail must be zeroed");
        let c = inj.counts();
        assert!(c.transients >= 1 && c.short_reads >= 1, "{c:?}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn virtual_clock_makes_throttling_instant() {
        use crate::storage::fault::VirtualClock;
        use std::sync::Arc;
        let data = vec![1u8; 32 * 1024];
        let path = tmpfile(&data);
        let mut t = ThrottledFile::new(
            FlashFile::open(&path).unwrap(),
            UfsModel::new(oneplus_12().ufs),
            CoreClass::Big,
        );
        let clock = Arc::new(VirtualClock::new());
        t.set_clock(Arc::clone(&clock));
        let start = Instant::now();
        let mut buf = [0u8; 4096];
        t.read_at(0, &mut buf).unwrap();
        assert!(start.elapsed().as_secs_f64() < 0.05, "must not block");
        assert!(clock.slept_s() > 0.0, "modeled latency must be accounted");
        std::fs::remove_file(path).ok();
    }
}
