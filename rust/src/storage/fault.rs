//! Injectable fault domain for the flash I/O path.
//!
//! PowerInfer-2 puts flash reads on the token critical path, and phones
//! are a hostile I/O environment (background-app contention, thermal
//! throttling, latency spikes — see the COTS device study in PAPERS.md).
//! This module gives the storage→offload→engine path a programmable,
//! *deterministic* failure model:
//!
//! - [`Clock`]: the injectable time source. Every sleep on the storage/
//!   offload path (UFS throttling, retry backoff, injected latency)
//!   routes through a `Clock`, so tests and the model checker swap in
//!   [`VirtualClock`] and stay instant and deterministic. `pi2 check`'s
//!   `sleep-retry` lint rule enforces the routing: [`SystemClock::sleep`]
//!   is the one justified `thread::sleep` site in `storage/`/`offload/`.
//! - [`FaultInjector`]: a seeded, per-site programmable fault source
//!   layered over [`crate::storage::ThrottledFile`]. It can inject
//!   transient `EIO`-style failures, torn (short) reads, latency spikes,
//!   and stuck reads that block past any I/O deadline. Decisions are a
//!   pure function of (seed, draw order), so a failing schedule replays
//!   from its seed.
//! - [`RetryPolicy`]: the bounded-retry/exponential-backoff ladder the
//!   verified store read uses for transient faults, plus the per-read
//!   I/O deadline past which the engine degrades instead of waiting.
//!
//! `PI2_FAULT_SEED` (env) arms a default chaos profile — 10% transient
//! faults plus occasional latency spikes on cluster reads — which CI's
//! chaos smoke job uses to run the serving integration tests under
//! injected faults with a fixed seed.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::prng::Rng;

/// Injectable time source: real on the serving path, virtual in tests
/// and the checker. `Debug` is required so storage handles that embed a
/// `dyn Clock` keep their derived `Debug`.
pub trait Clock: Send + Sync + std::fmt::Debug {
    /// Monotonic seconds since the clock's epoch.
    fn now_s(&self) -> f64;
    /// Block (or virtually advance) for `d`.
    fn sleep(&self, d: Duration);
}

/// Wall-clock [`Clock`] — the serving default.
#[derive(Debug)]
pub struct SystemClock {
    t0: Instant,
}

impl SystemClock {
    pub fn new() -> SystemClock {
        SystemClock { t0: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        SystemClock::new()
    }
}

impl Clock for SystemClock {
    fn now_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    fn sleep(&self, d: Duration) {
        // pi2-lint: allow(sleep-retry): the injectable clock's single
        // real sleep site — every storage/offload backoff and throttle
        // delay funnels through here so swapping the clock makes the
        // whole path virtual
        std::thread::sleep(d);
    }
}

/// Virtual [`Clock`]: `sleep` advances time without blocking. Tests and
/// fault schedules run in microseconds regardless of modeled latency.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now_us: AtomicU64,
    /// Total virtually-slept microseconds (what a real clock would have
    /// blocked for) — lets tests assert backoff arithmetic.
    slept_us: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Seconds this clock has virtually slept so far.
    pub fn slept_s(&self) -> f64 {
        self.slept_us.load(Ordering::Relaxed) as f64 * 1e-6
    }
}

impl Clock for VirtualClock {
    fn now_s(&self) -> f64 {
        self.now_us.load(Ordering::Relaxed) as f64 * 1e-6
    }

    fn sleep(&self, d: Duration) {
        let us = d.as_micros().min(u128::from(u64::MAX)) as u64;
        self.now_us.fetch_add(us, Ordering::Relaxed);
        self.slept_us.fetch_add(us, Ordering::Relaxed);
    }
}

/// Where on the flash path a fault is injected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultSite {
    /// Raw positioned reads through `ThrottledFile` (weight bundles).
    FlashRead,
    /// Cluster-record reads through `NeuronStore`.
    ClusterRead,
}

impl std::fmt::Display for FaultSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultSite::FlashRead => write!(f, "flash-read"),
            FaultSite::ClusterRead => write!(f, "cluster-read"),
        }
    }
}

/// Per-site fault programming. Rates are independent probabilities per
/// read, drawn in a fixed order (transient, short, stuck, spike) from
/// the injector's seeded stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Probability a read fails with a transient (retryable) error.
    pub transient_rate: f64,
    /// Probability a read is torn: only a prefix of the buffer lands,
    /// the tail stays zeroed — what record checksums exist to catch.
    pub short_read_rate: f64,
    /// Probability a read blocks for `stuck_s` before completing —
    /// meant to overrun the caller's I/O deadline.
    pub stuck_rate: f64,
    pub stuck_s: f64,
    /// Probability of a latency spike of `spike_s` (read still succeeds).
    pub spike_rate: f64,
    pub spike_s: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            transient_rate: 0.0,
            short_read_rate: 0.0,
            stuck_rate: 0.0,
            stuck_s: 0.25,
            spike_rate: 0.0,
            spike_s: 0.005,
        }
    }
}

impl FaultSpec {
    /// Transient faults only, at `rate` — the acceptance-gate profile.
    pub fn transient(rate: f64) -> FaultSpec {
        FaultSpec { transient_rate: rate, ..FaultSpec::default() }
    }

    fn is_quiet(&self) -> bool {
        self.transient_rate <= 0.0
            && self.short_read_rate <= 0.0
            && self.stuck_rate <= 0.0
            && self.spike_rate <= 0.0
    }
}

/// What the injector decided for one read.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultDecision {
    /// Fail with a transient (retryable) error.
    Transient,
    /// Deliver only `keep_frac` of the buffer; zero the tail.
    ShortRead { keep_frac: f64 },
    /// Sleep `delay_s` through the clock, then read normally. `stuck`
    /// marks delays programmed to overrun the caller's I/O deadline.
    Delay { delay_s: f64, stuck: bool },
}

/// Injection counters (what actually fired), for `stats` and tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    pub transients: u64,
    pub short_reads: u64,
    pub stuck_reads: u64,
    pub spikes: u64,
}

struct InjectorState {
    rng: Rng,
    specs: BTreeMap<FaultSite, FaultSpec>,
}

/// Seeded, per-site programmable fault source. Thread-safe: the I/O
/// threads that consult it only contend on a short internal lock, and
/// decisions are deterministic in (seed, global draw order).
pub struct FaultInjector {
    state: Mutex<InjectorState>,
    transients: AtomicU64,
    short_reads: AtomicU64,
    stuck_reads: AtomicU64,
    spikes: AtomicU64,
}

impl std::fmt::Debug for FaultInjector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let c = self.counts();
        write!(f, "FaultInjector({c:?})")
    }
}

impl FaultInjector {
    pub fn new(seed: u64) -> FaultInjector {
        FaultInjector {
            state: Mutex::new(InjectorState {
                rng: Rng::new(seed ^ 0xFA17_D0_5EED),
                specs: BTreeMap::new(),
            }),
            transients: AtomicU64::new(0),
            short_reads: AtomicU64::new(0),
            stuck_reads: AtomicU64::new(0),
            spikes: AtomicU64::new(0),
        }
    }

    /// The CI chaos profile: `PI2_FAULT_SEED=<seed>` arms 10% transient
    /// faults on cluster reads plus occasional short latency spikes.
    /// Returns `None` when the variable is unset or unparsable.
    pub fn from_env() -> Option<Arc<FaultInjector>> {
        let seed: u64 = std::env::var("PI2_FAULT_SEED").ok()?.parse().ok()?;
        let inj = FaultInjector::new(seed);
        inj.set(
            FaultSite::ClusterRead,
            FaultSpec {
                transient_rate: 0.10,
                spike_rate: 0.02,
                spike_s: 2e-4,
                ..FaultSpec::default()
            },
        );
        Some(Arc::new(inj))
    }

    /// Program `site`; a quiet (all-zero) spec disarms it.
    pub fn set(&self, site: FaultSite, spec: FaultSpec) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        if spec.is_quiet() {
            st.specs.remove(&site);
        } else {
            st.specs.insert(site, spec);
        }
    }

    /// Decide one read's fate. `None` = read proceeds untouched.
    pub fn decide(&self, site: FaultSite) -> Option<FaultDecision> {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        let spec = *st.specs.get(&site)?;
        // fixed draw order keeps schedules replayable from the seed
        if st.rng.bool(spec.transient_rate) {
            drop(st);
            self.transients.fetch_add(1, Ordering::Relaxed);
            return Some(FaultDecision::Transient);
        }
        if st.rng.bool(spec.short_read_rate) {
            let frac = 0.25 + 0.5 * st.rng.f64();
            drop(st);
            self.short_reads.fetch_add(1, Ordering::Relaxed);
            return Some(FaultDecision::ShortRead { keep_frac: frac });
        }
        if st.rng.bool(spec.stuck_rate) {
            let s = spec.stuck_s;
            drop(st);
            self.stuck_reads.fetch_add(1, Ordering::Relaxed);
            return Some(FaultDecision::Delay { delay_s: s, stuck: true });
        }
        if st.rng.bool(spec.spike_rate) {
            let s = spec.spike_s;
            drop(st);
            self.spikes.fetch_add(1, Ordering::Relaxed);
            return Some(FaultDecision::Delay { delay_s: s, stuck: false });
        }
        None
    }

    /// What has fired so far.
    pub fn counts(&self) -> FaultCounts {
        FaultCounts {
            transients: self.transients.load(Ordering::Relaxed),
            short_reads: self.short_reads.load(Ordering::Relaxed),
            stuck_reads: self.stuck_reads.load(Ordering::Relaxed),
            spikes: self.spikes.load(Ordering::Relaxed),
        }
    }
}

/// Typed injected-fault error: the retry ladder downcasts to this to
/// tell a retryable transient from a real storage failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFault {
    pub site: FaultSite,
    /// Byte offset of the faulted read.
    pub offset: u64,
}

impl std::fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "injected transient I/O fault at {} offset {}",
            self.site, self.offset
        )
    }
}

impl std::error::Error for InjectedFault {}

/// Typed I/O-deadline error: a read (or its retry ladder) overran the
/// per-read time budget. The data — if any arrived — is discarded; the
/// engine degrades to resident weights instead of waiting on flash.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IoDeadlineExceeded {
    pub site: FaultSite,
    pub elapsed_s: f64,
    pub deadline_s: f64,
}

impl std::fmt::Display for IoDeadlineExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "I/O deadline exceeded at {}: {:.4}s elapsed > {:.4}s budget",
            self.site, self.elapsed_s, self.deadline_s
        )
    }
}

impl std::error::Error for IoDeadlineExceeded {}

/// Bounded-retry ladder for transient flash faults, plus the per-read
/// I/O deadline past which the caller degrades instead of waiting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 = fail fast).
    pub max_retries: u32,
    /// First backoff; doubles per retry. Slept through the [`Clock`].
    pub backoff_base_s: f64,
    /// Wall (clock) budget for one logical read including retries;
    /// 0 disables the deadline.
    pub deadline_s: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2, backoff_base_s: 0.005, deadline_s: 0.0 }
    }
}

impl RetryPolicy {
    /// Backoff before retry `attempt` (1-based): base · 2^(attempt−1).
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        self.backoff_base_s * f64::from(1u32 << attempt.saturating_sub(1).min(16))
    }

    /// Has the per-read deadline expired `elapsed_s` into the ladder?
    pub fn expired(&self, elapsed_s: f64) -> bool {
        self.deadline_s > 0.0 && elapsed_s > self.deadline_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_advances_without_blocking() {
        let c = VirtualClock::new();
        let wall = Instant::now();
        c.sleep(Duration::from_secs(3600));
        assert!(wall.elapsed().as_secs_f64() < 1.0, "must not really sleep");
        assert!((c.now_s() - 3600.0).abs() < 1e-6);
        assert!((c.slept_s() - 3600.0).abs() < 1e-6);
    }

    #[test]
    fn injector_is_deterministic_in_seed_and_counts_fires() {
        let run = |seed: u64| -> (Vec<Option<FaultDecision>>, FaultCounts) {
            let inj = FaultInjector::new(seed);
            inj.set(FaultSite::ClusterRead, FaultSpec::transient(0.5));
            let seq: Vec<_> =
                (0..64).map(|_| inj.decide(FaultSite::ClusterRead)).collect();
            (seq, inj.counts())
        };
        let (a, ca) = run(7);
        let (b, cb) = run(7);
        assert_eq!(a, b, "same seed must replay the same schedule");
        assert_eq!(ca, cb);
        assert!(ca.transients > 0, "a 50% rate over 64 reads must fire");
        let (c, _) = run(8);
        assert_ne!(a, c, "different seeds should diverge");
    }

    #[test]
    fn unprogrammed_sites_never_fault() {
        let inj = FaultInjector::new(3);
        inj.set(FaultSite::ClusterRead, FaultSpec::transient(1.0));
        assert_eq!(inj.decide(FaultSite::FlashRead), None);
        assert_eq!(
            inj.decide(FaultSite::ClusterRead),
            Some(FaultDecision::Transient)
        );
        // a quiet spec disarms
        inj.set(FaultSite::ClusterRead, FaultSpec::default());
        assert_eq!(inj.decide(FaultSite::ClusterRead), None);
    }

    #[test]
    fn retry_backoff_is_exponential_and_deadline_typed() {
        let p = RetryPolicy {
            max_retries: 3,
            backoff_base_s: 0.01,
            deadline_s: 0.5,
        };
        assert!((p.backoff_s(1) - 0.01).abs() < 1e-12);
        assert!((p.backoff_s(2) - 0.02).abs() < 1e-12);
        assert!((p.backoff_s(3) - 0.04).abs() < 1e-12);
        assert!(!p.expired(0.4));
        assert!(p.expired(0.6));
        let off = RetryPolicy { deadline_s: 0.0, ..p };
        assert!(!off.expired(1e9));
    }

    #[test]
    fn injected_fault_error_is_downcastable() {
        let err = anyhow::Error::new(InjectedFault {
            site: FaultSite::ClusterRead,
            offset: 4096,
        });
        let f = err.downcast_ref::<InjectedFault>().unwrap();
        assert_eq!(f.offset, 4096);
        assert!(format!("{f}").contains("cluster-read"));
    }
}
