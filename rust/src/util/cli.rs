//! Tiny CLI argument parser (no clap in the offline dependency set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        // note: `--flag value` binds greedily, so bare flags go last
        let a = parse("serve input.txt --model bamboo-7b --threads 4 --verbose");
        assert_eq!(a.positional, vec!["serve", "input.txt"]);
        assert_eq!(a.opt("model"), Some("bamboo-7b"));
        assert_eq!(a.opt_usize("threads", 1), 4);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--mem=7.5 --name=x");
        assert_eq!(a.opt_f64("mem", 0.0), 7.5);
        assert_eq!(a.opt("name"), Some("x"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.opt_or("x", "d"), "d");
        assert_eq!(a.opt_u64("n", 9), 9);
    }
}
