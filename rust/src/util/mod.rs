//! Small self-contained substrates: JSON, PRNG, statistics, CLI parsing.
//! (The offline dependency set has no serde/rand/clap, so the repo carries
//! its own minimal versions — each is tested in place.)

pub mod cli;
pub mod json;
pub mod prng;
pub mod stats;
