//! Deterministic PRNG (xoshiro256**) + distribution samplers.
//!
//! Every stochastic component in the simulator (activation sampling, cache
//! behaviour, workload generation) threads one of these through explicitly,
//! so whole experiment runs are reproducible from a single seed — the same
//! discipline the paper needs to average its 10-run evaluations.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-thread / per-layer rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Binomial(n, p) — exact for small n, normal approximation for large.
    pub fn binomial(&mut self, n: usize, p: f64) -> usize {
        if n == 0 || p <= 0.0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        if n <= 64 {
            (0..n).filter(|_| self.bool(p)).count()
        } else {
            let mean = n as f64 * p;
            let std = (n as f64 * p * (1.0 - p)).sqrt();
            let v = mean + std * self.normal();
            v.round().clamp(0.0, n as f64) as usize
        }
    }

    /// Exponential with the given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * self.f64().max(1e-300).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k << n assumed).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let i = self.below(n);
            if seen.insert(i) {
                out.push(i);
            }
        }
        out
    }

    /// Fill a slice with N(0, std) f32 values.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(3);
        let mean: f64 = (0..20_000).map(|_| r.f64()).sum::<f64>() / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let xs: Vec<f64> = (0..40_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn binomial_small_and_large_consistent() {
        let mut r = Rng::new(5);
        let small: f64 = (0..4000).map(|_| r.binomial(50, 0.3) as f64)
            .sum::<f64>() / 4000.0;
        let large: f64 = (0..4000).map(|_| r.binomial(5000, 0.3) as f64)
            .sum::<f64>() / 4000.0;
        assert!((small - 15.0).abs() < 0.5, "small {small}");
        assert!((large - 1500.0).abs() < 5.0, "large {large}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(6);
        let idx = r.sample_indices(1000, 100);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 100);
        assert!(idx.iter().all(|&i| i < 1000));
        // dense case
        let idx = r.sample_indices(10, 9);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 9);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
