//! Lightweight statistics: online moments, percentiles, fixed histograms.
//!
//! Used by the metrics layer for the paper's latency-distribution tables
//! (Table 5 reports mean/P50/P90/P99 over 1,024 decoded tokens).

/// Online mean/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a retained sample (fine at our scales: ≤ ~1e6).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    values: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Samples::default()
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.values
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.values.len();
        let rank = (p / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.values[lo]
        } else {
            let w = rank - lo as f64;
            self.values[lo] * (1.0 - w) + self.values[hi] * w
        }
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Largest sample (`NaN` when empty).
    pub fn max(&mut self) -> f64 {
        self.percentile(100.0)
    }

    /// Absorb every sample of `other` (merging per-serve distributions
    /// into a server-lifetime one).
    pub fn extend_from(&mut self, other: &Samples) {
        if other.values.is_empty() {
            return;
        }
        self.values.extend_from_slice(&other.values);
        self.sorted = false;
    }
}

/// Format a bytes count human-readably.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2}{}", UNITS[u])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_match_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0_f64).powi(2)).sum::<f64>() / 4.0;
        assert!((s.var() - direct_var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((s.percentile(90.0) - 90.1).abs() < 1e-9);
    }

    #[test]
    fn percentile_single_value() {
        let mut s = Samples::new();
        s.push(42.0);
        assert_eq!(s.percentile(99.0), 42.0);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512.0), "512.00B");
        assert_eq!(fmt_bytes(2048.0), "2.00KB");
        assert_eq!(fmt_bytes(3.5 * 1024.0 * 1024.0 * 1024.0), "3.50GB");
    }
}
