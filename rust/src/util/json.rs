//! Minimal JSON parser/serializer.
//!
//! No serde in the offline dependency set, so the repo carries its own
//! small JSON substrate. It covers the full JSON grammar (objects, arrays,
//! strings with escapes, numbers, bools, null) and is byte-oriented so the
//! multi-megabyte `artifacts/selftest.json` vector file parses quickly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index access; Null when out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Collect a numeric array into f32s (used for selftest vectors).
    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    /// Collect a numeric array into usizes (shapes).
    pub fn to_usize_vec(&self) -> Option<Vec<usize>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_usize()?);
        }
        Some(out)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, text: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{FFFD}'),
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // consume a run of plain bytes at once
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builders for emitting JSON.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(text: &str) -> Json {
    Json::Str(text.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("c"));
        assert_eq!(v.get("d"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"x\"y"],"n":-7}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn f32_vec() {
        let v = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.to_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
        assert!(Json::parse("[1, \"x\"]").unwrap().to_f32_vec().is_none());
    }

    #[test]
    fn big_array_fast() {
        let src = format!("[{}]", (0..100_000).map(|i| i.to_string())
            .collect::<Vec<_>>().join(","));
        let v = Json::parse(&src).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 100_000);
    }
}
