//! Bench: UFS timing model evaluation + real throttled file reads.
mod common;

use powerinfer2::config::{oneplus_12, CoreClass};
use powerinfer2::storage::{IoBurst, IoPattern, UfsModel};

fn main() {
    println!("# bench: UFS model");
    let ufs = UfsModel::new(oneplus_12().ufs);
    let burst = IoBurst {
        pattern: IoPattern::Random,
        block_bytes: 4096,
        count: 100,
        range_bytes: 1 << 30,
        core: CoreClass::Big,
        issuers: 1,
    };
    common::bench("burst_time_s/random_4k_x100", || {
        std::hint::black_box(ufs.burst_time_s(&burst));
    });
    let seq = IoBurst { pattern: IoPattern::Sequential, block_bytes: 512 * 1024,
                        count: 8, ..burst };
    common::bench("burst_time_s/seq_512k_x8", || {
        std::hint::black_box(ufs.burst_time_s(&seq));
    });
}
