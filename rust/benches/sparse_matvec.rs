//! Bench: the CPU-side sparse GLU kernel (cold neuron accumulation) —
//! the real engine's compute hot path. Reports effective GB/s.
mod common;

use powerinfer2::engine::real::accumulate_neuron;
use powerinfer2::util::prng::Rng;

fn main() {
    println!("# bench: cold-neuron sparse GLU kernel");
    let mut rng = Rng::new(1);
    for (b, h, neurons) in [(1usize, 512usize, 256usize), (4, 512, 256), (1, 4096, 64)] {
        let bundles: Vec<Vec<f32>> = (0..neurons)
            .map(|_| {
                let mut v = vec![0f32; 3 * h + 1];
                rng.fill_normal(&mut v, 0.05);
                v
            })
            .collect();
        let mut x = vec![0f32; b * h];
        rng.fill_normal(&mut x, 1.0);
        let mut y = vec![0f32; b * h];
        let r = common::bench(&format!("accumulate/{neurons}n_b{b}_h{h}"), || {
            for bu in &bundles {
                accumulate_neuron(bu, &x, b, h, &mut y);
            }
            std::hint::black_box(&y);
        });
        let bytes = (neurons * (3 * h + 1) * 4) as f64;
        println!("    → {:.2} GB/s weight streaming", bytes / r.min_ns);
    }
}
