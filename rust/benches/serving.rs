//! Bench: scheduler comparison — lockstep groups vs continuous batching
//! over the simulation engine on a mixed-length request trace — plus the
//! chunked-prefill admission-stall comparison.
//!
//! The scheduler metric is useful decode tokens per engine-second
//! (modeled device seconds), the quantity the two schedulers actually
//! trade: lockstep holds a group's slots until its longest member
//! finishes; continuous batching retires a finished slot at decode-step
//! granularity and admits the next queued request into it.
//!
//! The chunked-prefill metric is per-slot inter-token latency (ITL) on
//! the engine clock: with synchronous admission every mid-flight
//! admission stalls the in-flight streams for the newcomer's whole
//! prompt; with `prefill_chunk = N` the prompt installs N tokens at a
//! time between decode steps, bounding the stall.
//!
//! The watermark scenario pits optimistic (evict-and-recompute) KV
//! admission against worst-case reservation on the same tight pool and
//! records admitted concurrency, preemption/restore counts, recompute
//! tokens, and TTFT percentiles to `BENCH_kv_preemption.json`.
//!
//! The concurrency scenario drives the real TCP serving path — accept
//! loop, per-connection reader/writer threads, the shared admission
//! queue — with 1/4/16 concurrent clients and records client-observed
//! TTFT, server-side queue wait, and shed counts to
//! `BENCH_serve_concurrency.json`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use powerinfer2::config::{bamboo_7b, oneplus_12, RuntimeConfig};
use powerinfer2::coordinator::{Coordinator, ScheduleMode, Server};
use powerinfer2::engine::SimEngine;
use powerinfer2::serve::{Engine, InferenceRequest};
use powerinfer2::trace::{mixed_length_mix, with_poisson_arrivals, Request, TaskKind};
use powerinfer2::util::json::{arr, num, obj, s, Json};
use powerinfer2::util::stats::Samples;

fn main() {
    println!("# bench: serving scheduler (sim engine, mixed-length trace)");
    let trace = mixed_length_mix(24, 7);
    let vocab = bamboo_7b().vocab;
    let requests: Vec<InferenceRequest> = trace
        .iter()
        .map(|r| InferenceRequest::from_trace(r, vocab, 64))
        .collect();
    let mut tps = Vec::new();
    for mode in [ScheduleMode::Lockstep, ScheduleMode::Continuous] {
        let cfg = RuntimeConfig { max_batch: 4, ..Default::default() };
        let engine = SimEngine::new(oneplus_12(), bamboo_7b(), cfg);
        let mut coord = Coordinator::with_mode(engine, mode);
        let report = coord.serve_collect(&requests).unwrap();
        let engine_tokens = coord.engine.stats().decode_tokens;
        println!(
            "{:<11} {:>5} useful tokens ({:>5} decoded)  \
             {:>8.3} engine-s  {:>8.1} tok/s",
            mode.as_str(),
            report.decode_tokens,
            engine_tokens,
            report.decode_s,
            report.decode_tps(),
        );
        tps.push(report.decode_tps());
    }
    println!("continuous / lockstep: {:.2}×", tps[1] / tps[0].max(1e-12));

    // chunked prefill vs synchronous admission under mid-flight Poisson
    // admissions: long prompts keep arriving while earlier streams
    // decode, so every admission either stalls the in-flight streams for
    // its whole prompt (chunk 0) or for at most one chunk per step.
    // ITL is on the engine clock (modeled seconds), so the comparison is
    // deterministic up to arrival interleaving. Run at the memory-rich
    // operating point (FFN resident): with weights streamed from flash
    // the per-pass weight stream dominates prefill whatever the chunk
    // size, and chunking buys little — the knob matters exactly where
    // prefill cost scales with tokens.
    println!("# bench: chunked prefill vs synchronous admit (mid-flight Poisson admissions)");
    let long_prompts: Vec<Request> = (0..16)
        .map(|id| Request {
            id,
            task: TaskKind::Code,
            prompt_tokens: 128 + (id * 37) % 192,
            output_tokens: 12 + (id * 7) % 20,
            arrival_s: 0.0,
        })
        .collect();
    let arrivals = with_poisson_arrivals(long_prompts, 3000.0, 5);
    let poisson_requests: Vec<InferenceRequest> = arrivals
        .iter()
        .map(|r| InferenceRequest::from_trace(r, vocab, 512))
        .collect();
    let mut max_itl = Vec::new();
    for chunk in [0usize, 32, 64] {
        let cfg = RuntimeConfig {
            max_batch: 4,
            offload_ffn_frac: 0.0,
            ..Default::default()
        };
        let engine = SimEngine::new(oneplus_12(), bamboo_7b(), cfg);
        let mut coord = Coordinator::new(engine).with_prefill_chunk(chunk);
        let mut report = coord.serve_collect(&poisson_requests).unwrap();
        let itl = &mut report.serving.itl_ms;
        let (p50, p99, max) =
            (itl.percentile(50.0), itl.percentile(99.0), itl.max());
        println!(
            "prefill-chunk {chunk:>3}: ITL p50 {p50:>7.1}ms  p99 {p99:>7.1}ms  \
             max {max:>7.1}ms  ({} deferred admissions, {} chunks, \
             {:>6.1} tok/s)",
            report.deferred_admissions,
            report.prefill_chunks,
            report.decode_tps(),
        );
        max_itl.push(max);
    }
    println!(
        "max-ITL reduction vs synchronous: {:.1}× (chunk 32), {:.1}× (chunk 64)",
        max_itl[0] / max_itl[1].max(1e-12),
        max_itl[0] / max_itl[2].max(1e-12),
    );

    // paged-KV pool under a tight memory budget: admission gates on
    // blocks-free, deferring instead of over-committing
    println!("# bench: paged KV pool pressure (continuous batching)");
    for blocks in [16usize, 32, 1024] {
        let cfg = RuntimeConfig {
            max_batch: 4,
            kv_block_tokens: 16,
            kv_pool_blocks: blocks,
            ..Default::default()
        };
        let engine = SimEngine::new(oneplus_12(), bamboo_7b(), cfg);
        let mut coord = Coordinator::new(engine);
        let report = coord.serve_collect(&requests).unwrap();
        let pool = coord.engine.kv_pool().unwrap();
        println!(
            "pool {blocks:>5} blocks: {:>8.1} tok/s  \
             {:>3} admission stalls  free-after-drain {:>4}  share {:>5.1}%",
            report.decode_tps(),
            report.kv_admission_stalls,
            pool.free_blocks,
            pool.share_rate() * 100.0,
        );
    }

    // watermark KV admission vs worst-case reservation on the same tight
    // pool: reservation gates each admission on every in-flight row's
    // remaining worst-case growth, so the pool caps live concurrency
    // well below max_batch; watermark admission leases only the prompt's
    // blocks and admits while the pool sits below the watermark, letting
    // decode growth run to exhaustion where the scheduler preempts a
    // victim and restores it later by recompute. The trade the JSON
    // records: strictly more admitted concurrency (peak_live) for
    // recompute work and inflated TTFT on the preempted sequences.
    println!("# bench: watermark KV admission (evict-and-recompute vs worst-case reservation)");
    let wm_requests: Vec<InferenceRequest> = (0..12)
        .map(|id| InferenceRequest::new(id, vec![id as u32 + 1, 2, 3, 4], 8))
        .collect();
    let mut wm_rows = Vec::new();
    let mut wm_peaks = Vec::new();
    for (label, frac) in [("reservation", 0.0f64), ("watermark-0.75", 0.75)] {
        let cfg = RuntimeConfig {
            max_batch: 4,
            kv_block_tokens: 4,
            kv_pool_blocks: 8,
            kv_watermark_frac: frac,
            ..Default::default()
        };
        let engine = SimEngine::new(oneplus_12(), bamboo_7b(), cfg);
        let mut coord = Coordinator::new(engine).with_kv_watermark(frac);
        let mut report = coord.serve_collect(&wm_requests).unwrap();
        let (t50, t99) = (
            report.serving.ttft_ms.percentile(50.0),
            report.serving.ttft_ms.percentile(99.0),
        );
        let tp99 = if report.ttft_preempted_ms.is_empty() {
            0.0
        } else {
            report.ttft_preempted_ms.percentile(99.0)
        };
        println!(
            "{label:>14}: peak live {:>2}  {:>3} preemptions \
             {:>3} restores  {:>4} recompute tok  {:>7.1} tok/s  \
             TTFT p50 {t50:>6.1}ms p99 {t99:>6.1}ms \
             (preempted p99 {tp99:>6.1}ms)",
            report.peak_live,
            report.preemptions,
            report.restores,
            report.recompute_tokens,
            report.decode_tps(),
        );
        wm_peaks.push(report.peak_live);
        wm_rows.push(obj(vec![
            ("scenario", s(label)),
            ("kv_watermark_frac", num(frac)),
            ("peak_live", num(report.peak_live as f64)),
            ("preemptions", num(report.preemptions as f64)),
            ("restores", num(report.restores as f64)),
            ("recompute_tokens", num(report.recompute_tokens as f64)),
            ("kv_admission_stalls", num(report.kv_admission_stalls as f64)),
            ("decode_tps", num(report.decode_tps())),
            ("ttft_ms_p50", num(t50)),
            ("ttft_ms_p99", num(t99)),
            ("ttft_preempted_ms_p99", num(tp99)),
        ]));
    }
    assert!(
        wm_peaks[1] > wm_peaks[0],
        "watermark admission must admit strictly more concurrency than \
         worst-case reservation ({} vs {})",
        wm_peaks[1],
        wm_peaks[0],
    );
    println!(
        "admitted concurrency: {} (watermark) vs {} (reservation)",
        wm_peaks[1], wm_peaks[0],
    );
    let out = obj(vec![
        ("bench", s("kv_preemption")),
        ("engine", s("sim")),
        ("model", s("bamboo-7b")),
        ("device", s("oneplus12")),
        ("max_batch", num(4.0)),
        ("kv_pool_blocks", num(8.0)),
        ("kv_block_tokens", num(4.0)),
        ("requests", num(wm_requests.len() as f64)),
        ("scenarios", arr(wm_rows)),
    ]);
    std::fs::write("BENCH_kv_preemption.json", format!("{out}\n")).unwrap();
    println!("wrote BENCH_kv_preemption.json");

    // offload streaming: cluster-granular cold-FFN residency at capped
    // resident budgets (64 and 512 clusters, well below the full FFN)
    // vs the per-neuron bundle baseline. The policy is exact — token
    // streams are identical — so what moves between scenarios is the
    // residency and I/O arithmetic the JSON below records.
    println!("# bench: offload streaming (cluster residency budgets)");
    let mut scenarios = Vec::new();
    for (label, streaming, resident) in
        [("off", false, 0usize), ("on-64", true, 64), ("on-512", true, 512)]
    {
        let cfg = RuntimeConfig {
            max_batch: 4,
            offload_streaming: streaming,
            offload_resident_clusters: resident,
            ..Default::default()
        };
        let engine = SimEngine::new(oneplus_12(), bamboo_7b(), cfg);
        let mut coord = Coordinator::new(engine);
        let mut report = coord.serve_collect(&requests).unwrap();
        let ttft = &mut report.serving.ttft_ms;
        let (t50, t99) = (ttft.percentile(50.0), ttft.percentile(99.0));
        let itl = &mut report.serving.itl_ms;
        let (i50, i99) = (itl.percentile(50.0), itl.percentile(99.0));
        println!(
            "offload {label:>6}: {:>7.1} tok/s  TTFT p50 {t50:>6.1}ms \
             p99 {t99:>6.1}ms  ITL p50 {i50:>5.1}ms p99 {i99:>5.1}ms  \
             hit {:>5.1}%  {:>7.1} MB streamed",
            report.decode_tps(),
            report.offload_cache_hit_rate * 100.0,
            report.offload_bytes_streamed as f64 / 1e6,
        );
        scenarios.push(obj(vec![
            ("scenario", s(label)),
            ("offload_streaming", Json::Bool(streaming)),
            ("resident_clusters", num(resident as f64)),
            ("decode_tps", num(report.decode_tps())),
            ("ttft_ms_p50", num(t50)),
            ("ttft_ms_p99", num(t99)),
            ("itl_ms_p50", num(i50)),
            ("itl_ms_p99", num(i99)),
            ("cache_hit_rate", num(report.offload_cache_hit_rate)),
            ("bytes_streamed", num(report.offload_bytes_streamed as f64)),
            ("overlap_ratio", num(report.offload_overlap_ratio)),
            ("stall_s", num(report.offload_stall_s)),
        ]));
    }
    let out = obj(vec![
        ("bench", s("decode_offload")),
        ("engine", s("sim")),
        ("model", s("bamboo-7b")),
        ("device", s("oneplus12")),
        ("scenarios", arr(scenarios)),
    ]);
    std::fs::write("BENCH_decode_offload.json", format!("{out}\n")).unwrap();
    println!("wrote BENCH_decode_offload.json");

    // fault-tolerant flash I/O: seeded transient-fault schedules over
    // the offload streaming path. The retry/degrade policy is exact —
    // useful token counts are identical at every fault rate — so the
    // JSON records the price instead: retry re-billing, degraded
    // fetches, and (for the persistent-failure run) the engine-wide
    // DegradedMode latch that drops streaming back to resident weights
    // mid-serve. Stalls are what advance the persistent-failure
    // counter; transient retries never do.
    println!("# bench: fault degradation (seeded faults over offload streaming)");
    let mut fd_rows = Vec::new();
    let mut fd_tokens = Vec::new();
    let mut fd_degraded = Vec::new();
    for (label, rate, stalls, threshold) in [
        ("clean", 0.0f64, 0u32, 0usize),
        ("transient-1pct", 0.01, 0, 0),
        ("transient-10pct", 0.10, 0, 0),
        ("persistent", 0.10, 16, 8),
    ] {
        let cfg = RuntimeConfig {
            max_batch: 4,
            offload_streaming: true,
            offload_resident_clusters: 64,
            io_failure_threshold: threshold,
            ..Default::default()
        };
        let mut engine = SimEngine::new(oneplus_12(), bamboo_7b(), cfg);
        engine.set_io_fault_rate(rate, 11);
        for _ in 0..stalls {
            engine.arm_io_stall();
        }
        let mut coord = Coordinator::new(engine);
        let mut report = coord.serve_collect(&requests).unwrap();
        let st = coord.engine.stats();
        let ttft = &mut report.serving.ttft_ms;
        let (t50, t99) = (ttft.percentile(50.0), ttft.percentile(99.0));
        println!(
            "{label:>15}: {:>7.1} tok/s  TTFT p50 {t50:>6.1}ms \
             p99 {t99:>6.1}ms  {:>4} retries  {:>3} degraded fetches  \
             degraded {}",
            report.decode_tps(),
            st.offload_io_retries,
            st.offload_degraded_fetches,
            st.offload_degraded,
        );
        fd_tokens.push(report.decode_tokens);
        fd_degraded.push(st.offload_degraded);
        fd_rows.push(obj(vec![
            ("scenario", s(label)),
            ("io_fault_rate", num(rate)),
            ("armed_stalls", num(stalls as f64)),
            ("io_failure_threshold", num(threshold as f64)),
            ("decode_tps", num(report.decode_tps())),
            ("decode_tokens", num(report.decode_tokens as f64)),
            ("ttft_ms_p50", num(t50)),
            ("ttft_ms_p99", num(t99)),
            ("io_retries", num(st.offload_io_retries as f64)),
            ("degraded_fetches", num(st.offload_degraded_fetches as f64)),
            ("bytes_streamed", num(st.offload_bytes_streamed as f64)),
            ("degraded", Json::Bool(st.offload_degraded)),
        ]));
    }
    assert!(
        fd_tokens.iter().all(|&t| t == fd_tokens[0]),
        "fault handling changed useful token counts: {fd_tokens:?}"
    );
    assert_eq!(
        fd_degraded,
        vec![false, false, false, true],
        "only the persistent run may latch DegradedMode"
    );
    let out = obj(vec![
        ("bench", s("fault_degradation")),
        ("engine", s("sim")),
        ("model", s("bamboo-7b")),
        ("device", s("oneplus12")),
        ("max_batch", num(4.0)),
        ("resident_clusters", num(64.0)),
        ("fault_seed", num(11.0)),
        ("scenarios", arr(fd_rows)),
    ]);
    std::fs::write("BENCH_fault_degradation.json", format!("{out}\n"))
        .unwrap();
    println!("wrote BENCH_fault_degradation.json");

    // concurrent connection serving over real sockets: N clients, each
    // streaming a few requests back-to-back through the shared admission
    // queue. The queue depth is kept tight (8) so the 16-client point
    // actually exercises load shedding — shed requests are answered with
    // a typed {"error","code":"shed"} line and retried by the client,
    // which is the protocol's backpressure loop.
    println!("# bench: concurrent connection serving (TCP, shared admission queue)");
    const PER_CLIENT: usize = 4;
    const QUEUE_DEPTH: usize = 8;
    let mut rows = Vec::new();
    for clients in [1usize, 4, 16] {
        let cfg = RuntimeConfig { max_batch: 4, ..Default::default() };
        let mut server =
            Server::<SimEngine>::sim(oneplus_12(), bamboo_7b(), cfg);
        server.set_limits(32, 0, QUEUE_DEPTH);
        let (ready_tx, ready_rx) = mpsc::channel();
        let server_thread = std::thread::spawn(move || {
            server.run("127.0.0.1:0", Some(ready_tx)).unwrap();
        });
        let addr = ready_rx.recv().unwrap();
        let t0 = Instant::now();
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                std::thread::spawn(move || {
                    let mut conn = TcpStream::connect(addr).unwrap();
                    let mut reader =
                        BufReader::new(conn.try_clone().unwrap());
                    let mut ttfts = Vec::new();
                    let (mut tokens, mut sheds) = (0usize, 0usize);
                    for r in 0..PER_CLIENT {
                        let req = format!(
                            "{{\"prompt\": \"client {c} request {r}\", \
                             \"max_tokens\": 8, \"stream\": true}}"
                        );
                        let sent = Instant::now();
                        let mut retries = 0usize;
                        'attempt: loop {
                            writeln!(conn, "{req}").unwrap();
                            let mut first = true;
                            loop {
                                let mut line = String::new();
                                assert!(
                                    reader.read_line(&mut line).unwrap() > 0,
                                    "server hung up mid-request"
                                );
                                let ev = Json::parse(&line).unwrap();
                                if ev.get("error").as_str().is_some() {
                                    // typed refusal: breathe and retry
                                    sheds += 1;
                                    retries += 1;
                                    assert!(retries < 500, "shed forever");
                                    std::thread::sleep(
                                        Duration::from_millis(2),
                                    );
                                    continue 'attempt;
                                }
                                if first {
                                    ttfts.push(
                                        sent.elapsed().as_secs_f64() * 1e3,
                                    );
                                    first = false;
                                }
                                match ev.get("event").as_str() {
                                    Some("token") => tokens += 1,
                                    Some("done") => break 'attempt,
                                    _ => {}
                                }
                            }
                        }
                    }
                    (ttfts, tokens, sheds)
                })
            })
            .collect();
        let mut ttft = Samples::default();
        let (mut tokens, mut client_sheds) = (0usize, 0usize);
        for h in handles {
            let (t, toks, sheds) = h.join().unwrap();
            for v in t {
                ttft.push(v);
            }
            tokens += toks;
            client_sheds += sheds;
        }
        let wall = t0.elapsed().as_secs_f64();
        // server-side queue percentiles and shed counter, then shutdown
        let mut ctl = TcpStream::connect(addr).unwrap();
        let mut creader = BufReader::new(ctl.try_clone().unwrap());
        writeln!(ctl, "{{\"cmd\": \"stats\"}}").unwrap();
        let mut line = String::new();
        creader.read_line(&mut line).unwrap();
        let stats = Json::parse(&line).unwrap();
        writeln!(ctl, "{{\"cmd\": \"shutdown\"}}").unwrap();
        let mut ack = String::new();
        let _ = creader.read_line(&mut ack);
        server_thread.join().unwrap();
        let q = stats.get("queue");
        let qw50 = q.get("wait_ms_p50").as_f64().unwrap_or(0.0);
        let qw99 = q.get("wait_ms_p99").as_f64().unwrap_or(0.0);
        let shed = q.get("shed").as_f64().unwrap_or(0.0);
        let (t50, t99) =
            (ttft.percentile(50.0), ttft.percentile(99.0));
        let tok_s = tokens as f64 / wall.max(1e-9);
        println!(
            "clients {clients:>2}: {tok_s:>7.1} tok/s wall  TTFT p50 \
             {t50:>6.1}ms p99 {t99:>6.1}ms  queue-wait p50 {qw50:>6.1}ms \
             p99 {qw99:>6.1}ms  shed {shed:>3.0} (clients saw {client_sheds})"
        );
        rows.push(obj(vec![
            ("clients", num(clients as f64)),
            ("requests", num((clients * PER_CLIENT) as f64)),
            ("wall_s", num(wall)),
            ("tok_s", num(tok_s)),
            ("ttft_ms_p50", num(t50)),
            ("ttft_ms_p99", num(t99)),
            ("queue_wait_ms_p50", num(qw50)),
            ("queue_wait_ms_p99", num(qw99)),
            ("shed", num(shed)),
        ]));
    }
    let out = obj(vec![
        ("bench", s("serve_concurrency")),
        ("engine", s("sim")),
        ("model", s("bamboo-7b")),
        ("device", s("oneplus12")),
        ("max_batch", num(4.0)),
        ("queue_depth", num(QUEUE_DEPTH as f64)),
        ("per_client_requests", num(PER_CLIENT as f64)),
        ("scenarios", arr(rows)),
    ]);
    std::fs::write("BENCH_serve_concurrency.json", format!("{out}\n"))
        .unwrap();
    println!("wrote BENCH_serve_concurrency.json");
}
