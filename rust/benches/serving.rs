//! Bench: scheduler comparison — lockstep groups vs continuous batching
//! over the simulation engine on a mixed-length request trace.
//!
//! The metric is useful decode tokens per engine-second (modeled device
//! seconds), the quantity the two schedulers actually trade: lockstep
//! keeps decoding full groups after short members finish; continuous
//! batching retires a finished slot at decode-step granularity and
//! admits the next queued request into it.

use powerinfer2::config::{bamboo_7b, oneplus_12, RuntimeConfig};
use powerinfer2::coordinator::{Coordinator, ScheduleMode};
use powerinfer2::engine::SimEngine;
use powerinfer2::serve::{Engine, InferenceRequest};
use powerinfer2::trace::mixed_length_mix;

fn main() {
    println!("# bench: serving scheduler (sim engine, mixed-length trace)");
    let trace = mixed_length_mix(24, 7);
    let vocab = bamboo_7b().vocab;
    let requests: Vec<InferenceRequest> = trace
        .iter()
        .map(|r| InferenceRequest::from_trace(r, vocab, 64))
        .collect();
    let mut tps = Vec::new();
    for mode in [ScheduleMode::Lockstep, ScheduleMode::Continuous] {
        let cfg = RuntimeConfig { max_batch: 4, ..Default::default() };
        let engine = SimEngine::new(oneplus_12(), bamboo_7b(), cfg);
        let mut coord = Coordinator::with_mode(engine, mode);
        let report = coord.serve_collect(&requests).unwrap();
        let engine_tokens = coord.engine.stats().decode_tokens;
        println!(
            "{:<11} {:>5} useful tokens ({:>5} decoded)  \
             {:>8.3} engine-s  {:>8.1} tok/s",
            mode.as_str(),
            report.decode_tokens,
            engine_tokens,
            report.decode_s,
            report.decode_tps(),
        );
        tps.push(report.decode_tps());
    }
    println!("continuous / lockstep: {:.2}×", tps[1] / tps[0].max(1e-12));

    // paged-KV pool under a tight memory budget: admission gates on
    // blocks-free, deferring instead of over-committing
    println!("# bench: paged KV pool pressure (continuous batching)");
    for blocks in [16usize, 32, 1024] {
        let cfg = RuntimeConfig {
            max_batch: 4,
            kv_block_tokens: 16,
            kv_pool_blocks: blocks,
            ..Default::default()
        };
        let engine = SimEngine::new(oneplus_12(), bamboo_7b(), cfg);
        let mut coord = Coordinator::new(engine);
        let report = coord.serve_collect(&requests).unwrap();
        let pool = coord.engine.kv_pool().unwrap();
        println!(
            "pool {blocks:>5} blocks: {:>8.1} tok/s  \
             {:>3} admission stalls  free-after-drain {:>4}  share {:>5.1}%",
            report.decode_tps(),
            report.kv_admission_stalls,
            pool.free_blocks,
            pool.share_rate() * 100.0,
        );
    }
}
