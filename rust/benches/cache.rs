//! Bench: neuron cache LRU — touched tens of thousands of times per
//! simulated token.
mod common;

use powerinfer2::cache::NeuronLru;
use powerinfer2::util::prng::Rng;

fn main() {
    println!("# bench: neuron LRU");
    for (universe, cap) in [(100_000usize, 10_000usize), (3_700_000, 400_000)] {
        let mut lru = NeuronLru::new(universe, cap);
        let mut rng = Rng::new(2);
        let ids: Vec<u32> = (0..4096).map(|_| rng.below(universe) as u32).collect();
        let r = common::bench(&format!("lru_access/u{universe}_c{cap}"), || {
            for &id in &ids {
                std::hint::black_box(lru.access(id));
            }
        });
        println!("    → {:.1} M accesses/s", 4096.0 / r.min_ns * 1e3);
    }
}
