//! Bench: neuron cache LRU — touched tens of thousands of times per
//! simulated token — and the paged KV pool's lease churn, which sits on
//! every admit/step/retire of the serving path.
mod common;

use powerinfer2::cache::NeuronLru;
use powerinfer2::kv::KvPool;
use powerinfer2::util::prng::Rng;

fn main() {
    println!("# bench: neuron LRU");
    for (universe, cap) in [(100_000usize, 10_000usize), (3_700_000, 400_000)] {
        let mut lru = NeuronLru::new(universe, cap);
        let mut rng = Rng::new(2);
        let ids: Vec<u32> = (0..4096).map(|_| rng.below(universe) as u32).collect();
        let r = common::bench(&format!("lru_access/u{universe}_c{cap}"), || {
            for &id in &ids {
                std::hint::black_box(lru.access(id));
            }
        });
        println!("    → {:.1} M accesses/s", 4096.0 / r.min_ns * 1e3);
    }

    println!("# bench: paged KV pool (admit + decode appends + release)");
    for (blocks, prompt, decode) in
        [(1024usize, 64usize, 128usize), (8192, 512, 1024)]
    {
        let mut pool = KvPool::new(blocks, 16, 0);
        let r = common::bench(
            &format!("kv_pool_lifecycle/b{blocks}_p{prompt}_d{decode}"),
            || {
                let prompt_ids: Vec<u32> = (0..prompt as u32).collect();
                let mut lease = pool.admit(&prompt_ids, 0).unwrap();
                for _ in 0..decode {
                    pool.append(&mut lease).unwrap();
                }
                std::hint::black_box(pool.free_blocks());
                pool.release(lease);
            },
        );
        let ops = (prompt / 16 + decode) as f64;
        println!("    → {:.1} M block-ops/s", ops / r.min_ns * 1e3);
    }
}
