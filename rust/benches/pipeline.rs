//! Bench: the 5-stage cluster pipeline scheduler (Fig.6) — the per-layer
//! hot-path of every simulated decode step.
mod common;

use powerinfer2::config::PipelineMode;
use powerinfer2::pipeline::{schedule, ClusterTask};

fn tasks(n: usize) -> Vec<ClusterTask> {
    (0..n)
        .map(|i| ClusterTask {
            pred_s: 1e-5,
            gate_io_s: if i % 2 == 0 { 0.0 } else { 5e-6 },
            gate_c_s: 2e-5,
            ud_io_s: if i % 2 == 0 { 0.0 } else { 5e-6 },
            ud_c_s: 4e-5,
        })
        .collect()
}

fn main() {
    println!("# bench: pipeline scheduler");
    for n in [8usize, 32, 128] {
        let t = tasks(n);
        for mode in [PipelineMode::None, PipelineMode::MatrixLevel,
                     PipelineMode::ClusterLevel] {
            common::bench(&format!("schedule/{mode:?}/{n}"), || {
                std::hint::black_box(schedule(&t, mode, 4));
            });
        }
    }
}
