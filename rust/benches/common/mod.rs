//! Minimal bench harness (criterion is not in the offline dependency
//! set): warm up, run until both a time and an iteration floor are met,
//! report mean/min per iteration.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub min_ns: f64,
}

pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    for _ in 0..3 {
        f(); // warmup
    }
    let mut times = Vec::new();
    let budget = std::time::Duration::from_millis(800);
    let start = Instant::now();
    while start.elapsed() < budget || times.len() < 10 {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
        if times.len() >= 10_000 {
            break;
        }
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters: times.len() as u64,
        mean_ns: mean,
        min_ns: min,
    };
    println!("{:<46} {:>7} iters  mean {:>10}  min {:>10}",
             r.name, r.iters, fmt_ns(r.mean_ns), fmt_ns(r.min_ns));
    r
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}
