//! Bench: end-to-end decode step — one per paper table (Fig.7's row
//! economics): the simulated engines for each system, plus the REAL
//! PJRT engine when artifacts are present.
mod common;

use std::path::Path;

use powerinfer2::config::{bamboo_7b, oneplus_12};
use powerinfer2::engine::real::{RealEngine, RealEngineOptions};
use powerinfer2::engine::SimEngine;
use powerinfer2::experiments::system_cfg;

fn main() {
    println!("# bench: decode step");
    for sys in ["powerinfer2", "llmflash", "llamacpp"] {
        let mut e = SimEngine::new(oneplus_12(), bamboo_7b(), system_cfg(sys));
        e.decode_step(1); // warm the plan/cache
        common::bench(&format!("sim_decode_step/{sys}"), || {
            std::hint::black_box(e.decode_step(1));
        });
    }
    let artifacts = Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        let wp = std::env::temp_dir().join("pi2_bench_weights.bin");
        let opts = RealEngineOptions { throttle_io: false, ..Default::default() };
        let mut e = RealEngine::new(artifacts, &wp, 1, opts).unwrap();
        let mut tok = vec![1u32];
        tok = e.decode_step(&tok).unwrap();
        let r = common::bench("real_decode_step/pjrt_b1", || {
            tok = e.decode_step(&tok).unwrap();
            if e.row_pos[0] >= e.dims.seq_max - 2 {
                e.reset().unwrap();
            }
        });
        println!("    → {:.1} tok/s real engine", 1e9 / r.mean_ns);
        std::fs::remove_file(wp).ok();
    }
}
