"""AOT path: HLO text emission + manifest integrity."""

import json
import os

import jax
import pytest

from compile import aot, model

TINY = aot.SELFTEST_DIMS


class TestHloEmission:
    def test_hlo_text_roundtrippable_format(self):
        # Every artifact must be HLO *text* with an ENTRY computation —
        # the format xla_extension 0.5.1's parser accepts.
        name, fn, arg_specs, _ = model.graph_table(TINY)[0]
        lowered = aot.lower_graph(fn, arg_specs)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ENTRY" in text
        # return_tuple=True → root is a tuple
        assert "tuple(" in text.replace(" ", "") or "tuple " in text

    def test_emit_table_writes_all_graphs(self, tmp_path):
        manifest = aot.emit_table(TINY, str(tmp_path))
        assert len(manifest["graphs"]) == len(model.graph_table(TINY))
        for entry in manifest["graphs"]:
            path = tmp_path / entry["file"]
            assert path.exists() and path.stat().st_size > 0
            assert entry["args"], entry["name"]
            assert entry["outputs"], entry["name"]

    def test_manifest_arg_shapes_match_specs(self, tmp_path):
        manifest = aot.emit_table(TINY, str(tmp_path))
        by_name = {e["name"]: e for e in manifest["graphs"]}
        for name, fn, arg_specs, meta in model.graph_table(TINY):
            entry = by_name[name]
            assert entry["meta"] == meta
            for (an, spec), recorded in zip(arg_specs, entry["args"]):
                assert recorded["name"] == an
                assert tuple(recorded["shape"]) == spec.shape
                assert recorded["dtype"] == spec.dtype.name


class TestSelftestVectors:
    @pytest.fixture(scope="class")
    def selftest(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        aot.emit_selftest(str(out))
        with open(out / "selftest" / "selftest.json") as f:
            return json.load(f)

    def test_cases_cover_every_graph_kind(self, selftest):
        names = [case["graph"] for case in selftest["cases"]]
        for prefix in ("decode_attn", "decode_ffn", "decode_dense",
                       "lm_head", "prefill_chunk"):
            assert any(n.startswith(prefix) for n in names), prefix

    def test_vectors_are_finite_and_sized(self, selftest):
        import math
        for case in selftest["cases"]:
            for arr in case["inputs"] + case["outputs"]:
                n = 1
                for s in arr["shape"]:
                    n *= s
                assert len(arr["data"]) == n
                assert all(math.isfinite(v) for v in arr["data"][:64])
