"""L2 correctness: layer graphs compose, decode ≡ prefill, shapes match."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

DIMS = model.ModelDims(
    hidden=32, inter=128, layers=2, heads=4, kv_heads=2,
    vocab=64, seq_max=16, prefill_chunk=8, batches=(1, 2), hot_ks=(128,),
    kv_block=4, kv_blocks=16,
)


def _rng(seed=0):
    return np.random.default_rng(seed)


def _pool(d):
    """Zeroed KV pool pair [NB, BS, NKV, DH]."""
    shape = (d.kv_blocks, d.kv_block, d.kv_heads, d.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def _tables(d, b):
    """Disjoint per-row block tables [b, M] avoiding the scratch block."""
    m = d.max_blocks
    return jnp.asarray(
        1 + np.arange(b * m, dtype=np.int32).reshape(b, m))


def _attn_weights(rng, d):
    h, kvd = d.hidden, d.kv_dim
    mk = lambda *s: jnp.asarray(rng.standard_normal(s) * (1.0 / np.sqrt(s[-1])),
                                jnp.float32)
    return dict(
        norm1=jnp.ones(h, jnp.float32),
        wq=mk(h, h), wk=mk(kvd, h), wv=mk(kvd, h), wo=mk(h, h),
        norm2=jnp.ones(h, jnp.float32),
    )


def _ffn_weights(rng, d, k=None):
    k = k or d.inter
    h = d.hidden
    mk = lambda *s: jnp.asarray(rng.standard_normal(s) * (1.0 / np.sqrt(s[-1])),
                                jnp.float32)
    return dict(gate=mk(k, h), up=mk(k, h),
                gate_bias=jnp.asarray(rng.standard_normal(k) * 0.1, jnp.float32),
                down=mk(k, h))


class TestRmsNormAndRope:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31))
    def test_rmsnorm_unit_rms(self, seed):
        x = jnp.asarray(_rng(seed).standard_normal((4, 32)) * 3, jnp.float32)
        y = model.rmsnorm(x, jnp.ones(32, jnp.float32))
        rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
        np.testing.assert_allclose(rms, jnp.ones(4), rtol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31), pos=st.integers(0, 100))
    def test_rope_preserves_norm(self, seed, pos):
        x = jnp.asarray(_rng(seed).standard_normal((2, 4, 16)), jnp.float32)
        y = model.rope(x, jnp.full((2,), pos, jnp.int32))
        np.testing.assert_allclose(
            jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-4)

    def test_rope_position_zero_is_identity(self):
        x = jnp.asarray(_rng(1).standard_normal((2, 4, 16)), jnp.float32)
        y = model.rope(x, jnp.zeros((2,), jnp.int32))
        np.testing.assert_allclose(y, x, rtol=1e-6, atol=1e-6)

    def test_rope_matches_ref(self):
        x = jnp.asarray(_rng(2).standard_normal((3, 4, 16)), jnp.float32)
        pos = jnp.asarray([0, 5, 11], jnp.int32)
        np.testing.assert_allclose(
            model.rope(x, pos), ref.ref_rope(x, pos), rtol=1e-5, atol=1e-6)


class TestDecodeAttnGraph:
    def test_shapes_and_paged_cache_insert(self):
        d = DIMS
        rng = _rng(3)
        w = _attn_weights(rng, d)
        b = 2
        x = jnp.asarray(rng.standard_normal((b, d.hidden)), jnp.float32)
        kp, vp = _pool(d)
        table = _tables(d, b)  # row 0 → blocks 1..4, row 1 → blocks 5..8
        # per-row positions: row 0 writes logical slot 5 (block 2, off 1),
        # row 1 writes logical slot 2 (block 5, off 2)
        pos = jnp.asarray([5, 2], jnp.int32)
        x_attn, ffn_in, kp2, vp2 = model.decode_attn(
            d, x, w["norm1"], w["wq"], w["wk"], w["wv"], w["wo"], w["norm2"],
            kp, vp, table, pos)
        assert x_attn.shape == (b, d.hidden)
        assert ffn_in.shape == (b, d.hidden)
        assert kp2.shape == kp.shape
        # each row touches exactly one slot of its own physical block
        assert not jnp.allclose(kp2[2, 1], 0.0)
        assert not jnp.allclose(kp2[5, 2], 0.0)
        assert not jnp.allclose(vp2[2, 1], 0.0)
        touched = np.zeros((d.kv_blocks, d.kv_block), bool)
        touched[2, 1] = touched[5, 2] = True
        flat = np.asarray(kp2).reshape(d.kv_blocks, d.kv_block, -1)
        for nb in range(d.kv_blocks):
            for s in range(d.kv_block):
                if not touched[nb, s]:
                    np.testing.assert_array_equal(flat[nb, s], 0.0)

    def test_row_output_independent_of_neighbour_position(self):
        """A row's attention output must depend only on its own blocks —
        the invariant that makes mid-flight admission exact."""
        d = DIMS
        rng = _rng(9)
        w = _attn_weights(rng, d)
        x = jnp.asarray(rng.standard_normal((2, d.hidden)), jnp.float32)
        shape = (d.kv_blocks, d.kv_block, d.kv_heads, d.head_dim)
        kp = jnp.asarray(rng.standard_normal(shape) * 0.3, jnp.float32)
        vp = jnp.asarray(rng.standard_normal(shape) * 0.3, jnp.float32)
        table = _tables(d, 2)
        args = [x, w["norm1"], w["wq"], w["wk"], w["wv"], w["wo"], w["norm2"]]
        a, _, _, _ = model.decode_attn(
            d, *args, kp, vp, table, jnp.asarray([4, 1], jnp.int32))
        b, _, _, _ = model.decode_attn(
            d, *args, kp, vp, table, jnp.asarray([4, 9], jnp.int32))
        np.testing.assert_allclose(a[0], b[0], rtol=1e-6, atol=1e-6)

    def test_paged_layout_equals_contiguous_layout(self):
        """Scattering a row's logical window across arbitrary pool blocks
        must attend identically to the contiguous (identity-table) layout
        — the invariant that makes block reuse and prefix sharing safe."""
        d = DIMS
        rng = _rng(12)
        w = _attn_weights(rng, d)
        b, m, bs = 2, d.max_blocks, d.kv_block
        x = jnp.asarray(rng.standard_normal((b, d.hidden)), jnp.float32)
        logical_k = rng.standard_normal(
            (b, d.seq_max, d.kv_heads, d.head_dim)).astype(np.float32) * 0.3
        logical_v = rng.standard_normal(
            (b, d.seq_max, d.kv_heads, d.head_dim)).astype(np.float32) * 0.3
        pos = jnp.asarray([9, 6], jnp.int32)
        args = [x, w["norm1"], w["wq"], w["wk"], w["wv"], w["wo"], w["norm2"]]

        def run(table_rows):
            kp, vp = _pool(d)
            table = jnp.asarray(np.asarray(table_rows, np.int32))
            for r in range(b):
                for j in range(m):
                    blk = int(table_rows[r][j])
                    kp = kp.at[blk].set(logical_k[r, j * bs:(j + 1) * bs])
                    vp = vp.at[blk].set(logical_v[r, j * bs:(j + 1) * bs])
            out, _, _, _ = model.decode_attn(d, *args, kp, vp, table, pos)
            return out

        contiguous = run([[1, 2, 3, 4], [5, 6, 7, 8]])
        scattered = run([[11, 3, 14, 7], [2, 9, 4, 13]])
        np.testing.assert_allclose(contiguous, scattered, rtol=1e-5,
                                   atol=1e-6)

    def test_shared_prefix_blocks_attend_identically(self):
        """Two rows mapping the same physical prefix block (prefix
        sharing) must each attend as if they owned a private copy."""
        d = DIMS
        rng = _rng(13)
        w = _attn_weights(rng, d)
        x0 = jnp.asarray(rng.standard_normal((1, d.hidden)), jnp.float32)
        x = jnp.concatenate([x0, x0], axis=0)
        kp, vp = _pool(d)
        prefix_k = rng.standard_normal(
            (d.kv_block, d.kv_heads, d.head_dim)).astype(np.float32)
        prefix_v = rng.standard_normal(
            (d.kv_block, d.kv_heads, d.head_dim)).astype(np.float32)
        kp = kp.at[3].set(prefix_k)
        vp = vp.at[3].set(prefix_v)
        # row 0 and row 1 share physical block 3 as their first block but
        # have private (distinct) tail blocks
        shared = jnp.asarray([[3, 4, 5, 6], [3, 7, 8, 9]], jnp.int32)
        # private copy of the prefix for the reference row
        kp_ref = kp.at[10].set(prefix_k)
        vp_ref = vp.at[10].set(prefix_v)
        private = jnp.asarray([[3, 4, 5, 6], [10, 7, 8, 9]], jnp.int32)
        pos = jnp.asarray([4, 4], jnp.int32)
        args = [x, w["norm1"], w["wq"], w["wk"], w["wv"], w["wo"], w["norm2"]]
        a, _, _, _ = model.decode_attn(d, *args, kp, vp, shared, pos)
        bref, _, _, _ = model.decode_attn(d, *args, kp_ref, vp_ref, private,
                                          pos)
        np.testing.assert_allclose(a[1], bref[1], rtol=1e-6, atol=1e-6)
        # both rows see the same history → identical outputs for same x
        np.testing.assert_allclose(a[0], a[1], rtol=1e-5, atol=1e-6)

    def test_ffn_in_is_normed_x_attn(self):
        d = DIMS
        rng = _rng(4)
        w = _attn_weights(rng, d)
        x = jnp.asarray(rng.standard_normal((1, d.hidden)), jnp.float32)
        kp, vp = _pool(d)
        x_attn, ffn_in, _, _ = model.decode_attn(
            d, x, w["norm1"], w["wq"], w["wk"], w["wv"], w["wo"], w["norm2"],
            kp, vp, _tables(d, 1), jnp.zeros((1,), jnp.int32))
        np.testing.assert_allclose(
            ffn_in, ref.ref_rmsnorm(x_attn, w["norm2"]), rtol=1e-5, atol=1e-6)


class TestDenseLayerEquivalence:
    def test_dense_layer_equals_attn_plus_full_ffn(self):
        """decode_layer_dense ≡ decode_attn + hot_ffn(I) + residual.

        This is the identity that lets the engine swap the QNN-style dense
        graph for the hybrid split without changing semantics.
        """
        d = DIMS
        rng = _rng(5)
        aw, fw = _attn_weights(rng, d), _ffn_weights(rng, d)
        x = jnp.asarray(rng.standard_normal((2, d.hidden)), jnp.float32)
        kp, vp = _pool(d)
        table = _tables(d, 2)
        pos = jnp.asarray([2, 3], jnp.int32)
        args = [x, aw["norm1"], aw["wq"], aw["wk"], aw["wv"], aw["wo"],
                aw["norm2"]]
        y_dense, kp_d, vp_d = model.decode_layer_dense(
            d, *args, fw["gate"], fw["up"], fw["gate_bias"], fw["down"],
            kp, vp, table, pos)
        x_attn, ffn_in, kp_a, vp_a = model.decode_attn(
            d, *args, kp, vp, table, pos)
        y_split = x_attn + model.decode_hot_ffn(
            d, ffn_in, fw["gate"], fw["up"], fw["gate_bias"], fw["down"])
        np.testing.assert_allclose(y_dense, y_split, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(kp_d, kp_a, rtol=1e-6)
        np.testing.assert_allclose(vp_d, vp_a, rtol=1e-6)

    def test_hot_plus_cold_partials_sum_to_full_ffn(self):
        """Splitting I into hot[0:k] on NPU + cold[k:] on CPU is exact."""
        d = DIMS
        rng = _rng(6)
        fw = _ffn_weights(rng, d)
        x = jnp.asarray(rng.standard_normal((2, d.hidden)), jnp.float32)
        full = ref.ref_hot_ffn(x, fw["gate"], fw["up"], fw["gate_bias"],
                               fw["down"])
        k = 64
        hot = ref.ref_hot_ffn(x, fw["gate"][:k], fw["up"][:k],
                              fw["gate_bias"][:k], fw["down"][:k])
        cold = ref.ref_hot_ffn(x, fw["gate"][k:], fw["up"][k:],
                               fw["gate_bias"][k:], fw["down"][k:])
        np.testing.assert_allclose(hot + cold, full, rtol=1e-4, atol=1e-5)


def _zero_prev(d):
    """Zeroed prefix K/V input pair [S, NKV, DH] for prefill_chunk."""
    shape = (d.seq_max, d.kv_heads, d.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def _prefill_full(d, x, args_w):
    """Whole-prompt prefill: one chunk at start 0 with an empty prefix."""
    kz, vz = _zero_prev(d)
    return model.prefill_chunk(d, x, *args_w, kz, vz,
                               jnp.zeros((1,), jnp.int32))


class TestPrefillDecodeConsistency:
    def test_prefill_then_decode_matches_all_prefill(self):
        """Token t computed by decode after a (t)-token prefill must equal
        token t of a (t+1)-token prefill — KV cache install + RoPE offsets
        + masked attention all have to line up for this to hold."""
        d = DIMS
        rng = _rng(7)
        aw, fw = _attn_weights(rng, d), _ffn_weights(rng, d)
        t = d.prefill_chunk
        x_full = jnp.asarray(rng.standard_normal((t, d.hidden)), jnp.float32)

        args_w = [aw["norm1"], aw["wq"], aw["wk"], aw["wv"], aw["wo"],
                  aw["norm2"], fw["gate"], fw["up"], fw["gate_bias"],
                  fw["down"]]
        y_full, k_full, v_full = _prefill_full(d, x_full, args_w)

        # prefill the first t-1 tokens into the row's leased pool blocks,
        # then decode token t-1 through the block table
        y_pre, k_pre, v_pre = _prefill_full(d, x_full[:t - 1], args_w)
        kp, vp = _pool(d)
        table = _tables(d, 1)  # row 0 → blocks 1..4
        bs = d.kv_block
        for p in range(t - 1):
            blk = 1 + p // bs
            kp = kp.at[blk, p % bs].set(k_pre[p])
            vp = vp.at[blk, p % bs].set(v_pre[p])
        x_attn, ffn_in, kp2, vp2 = model.decode_attn(
            d, x_full[t - 1:t], aw["norm1"], aw["wq"], aw["wk"], aw["wv"],
            aw["wo"], aw["norm2"], kp, vp, table,
            jnp.full((1,), t - 1, jnp.int32))
        y_dec = x_attn + model.decode_hot_ffn(
            d, ffn_in, fw["gate"], fw["up"], fw["gate_bias"], fw["down"])
        np.testing.assert_allclose(y_dec[0], y_full[t - 1], rtol=2e-3,
                                   atol=2e-4)
        blk, off = 1 + (t - 1) // bs, (t - 1) % bs
        np.testing.assert_allclose(kp2[blk, off], k_full[t - 1], rtol=1e-4,
                                   atol=1e-5)

    @pytest.mark.parametrize("split", [1, 3, 4, 7])
    def test_chunked_prefill_matches_whole_prompt(self, split):
        """Prefilling a prompt in two chunks — the second attending over
        the first's installed K/V through k_prev/v_prev — must reproduce
        the whole-prompt prefill, whatever the chunk boundary. This is
        the invariant that lets the serving layer slice prompt
        installation into bounded chunks between decode steps."""
        d = DIMS
        rng = _rng(9)
        aw, fw = _attn_weights(rng, d), _ffn_weights(rng, d)
        t = d.prefill_chunk
        x_full = jnp.asarray(rng.standard_normal((t, d.hidden)), jnp.float32)
        args_w = [aw["norm1"], aw["wq"], aw["wk"], aw["wv"], aw["wo"],
                  aw["norm2"], fw["gate"], fw["up"], fw["gate_bias"],
                  fw["down"]]
        y_full, k_full, v_full = _prefill_full(d, x_full, args_w)

        # chunk 1 at start 0, chunk 2 at start=split over chunk 1's K/V
        y1, k1, v1 = _prefill_full(d, x_full[:split], args_w)
        kp = jnp.zeros((d.seq_max, d.kv_heads, d.head_dim), jnp.float32)
        vp = jnp.zeros_like(kp)
        kp = kp.at[:split].set(k1)
        vp = vp.at[:split].set(v1)
        y2, k2, v2 = model.prefill_chunk(
            d, x_full[split:], *args_w, kp, vp,
            jnp.full((1,), split, jnp.int32))

        y = jnp.concatenate([y1, y2], axis=0)
        k = jnp.concatenate([k1, k2], axis=0)
        v = jnp.concatenate([v1, v2], axis=0)
        np.testing.assert_allclose(y, y_full, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(k, k_full, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(v, v_full, rtol=1e-5, atol=1e-6)

    def test_padded_chunk_rows_do_not_perturb_real_rows(self):
        """A right-padded chunk (fewer real tokens than the compiled T)
        must produce the same outputs for its real rows — padding only
        attends backwards, exactly like the serving layer's final partial
        chunk."""
        d = DIMS
        rng = _rng(10)
        aw, fw = _attn_weights(rng, d), _ffn_weights(rng, d)
        t = d.prefill_chunk
        args_w = [aw["norm1"], aw["wq"], aw["wk"], aw["wv"], aw["wo"],
                  aw["norm2"], fw["gate"], fw["up"], fw["gate_bias"],
                  fw["down"]]
        n = t - 3
        x = jnp.asarray(rng.standard_normal((n, d.hidden)), jnp.float32)
        y_exact, k_exact, _ = _prefill_full(d, x, args_w)
        x_pad = jnp.concatenate(
            [x, jnp.zeros((t - n, d.hidden), jnp.float32)], axis=0)
        y_pad, k_pad, _ = _prefill_full(d, x_pad, args_w)
        np.testing.assert_allclose(y_pad[:n], y_exact, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(k_pad[:n], k_exact, rtol=1e-5, atol=1e-6)


class TestLmHead:
    def test_logits_shape_and_value(self):
        d = DIMS
        rng = _rng(8)
        x = jnp.asarray(rng.standard_normal((2, d.hidden)), jnp.float32)
        nf = jnp.ones(d.hidden, jnp.float32)
        wlm = jnp.asarray(rng.standard_normal((d.vocab, d.hidden)) * 0.05,
                          jnp.float32)
        logits = model.lm_head(d, x, nf, wlm)
        assert logits.shape == (2, d.vocab)
        want = ref.ref_rmsnorm(x, nf) @ wlm.T
        np.testing.assert_allclose(logits, want, rtol=1e-5, atol=1e-5)


class TestGraphTable:
    def test_table_covers_grid(self):
        d = DIMS
        names = [g[0] for g in model.graph_table(d)]
        for b in d.batches:
            assert f"decode_attn_b{b}" in names
            assert f"decode_dense_b{b}" in names
            assert f"lm_head_b{b}" in names
            for k in d.hot_ks:
                assert f"decode_ffn_b{b}_k{k}" in names
        assert f"prefill_chunk_t{d.prefill_chunk}" in names
        # (attn + dense + lm_head + ffn·|hot_ks|) per batch + 1 prefill
        assert len(names) == len(d.batches) * (3 + len(d.hot_ks)) + 1

    def test_arg_specs_are_lowerable(self):
        d = DIMS
        for name, fn, arg_specs, _ in model.graph_table(d):
            out = jax.eval_shape(fn, *[s for _, s in arg_specs])
            assert jax.tree_util.tree_leaves(out), name

    def test_decode_graphs_declare_paged_kv_abi(self):
        """The ABI the rust engine guards on: decode graphs end with
        (k_pool, v_pool, block_table [B, M], pos [B])."""
        d = DIMS
        pool_shape = (d.kv_blocks, d.kv_block, d.kv_heads, d.head_dim)
        for name, _fn, arg_specs, meta in model.graph_table(d):
            if meta["kind"] not in ("decode_attn", "decode_layer_dense"):
                continue
            b = meta["batch"]
            names = [an for an, _ in arg_specs]
            assert names[-4:] == ["k_pool", "v_pool", "block_table", "pos"], \
                name
            assert arg_specs[-4][1].shape == pool_shape
            assert arg_specs[-3][1].shape == pool_shape
            assert arg_specs[-2][1].shape == (b, d.max_blocks)
            assert arg_specs[-1][1].shape == (b,)

    def test_validate_rejects_bad_dims(self):
        with pytest.raises(AssertionError):
            model.graph_table(dataclasses.replace(DIMS, hot_ks=(100,)))
        with pytest.raises(AssertionError):
            model.graph_table(dataclasses.replace(DIMS, heads=3))
        with pytest.raises(AssertionError):
            # block size must divide the logical window
            model.graph_table(dataclasses.replace(DIMS, kv_block=5))
