"""L2 correctness: layer graphs compose, decode ≡ prefill, shapes match."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

DIMS = model.ModelDims(
    hidden=32, inter=128, layers=2, heads=4, kv_heads=2,
    vocab=64, seq_max=16, prefill_chunk=8, batches=(1, 2), hot_ks=(128,),
)


def _rng(seed=0):
    return np.random.default_rng(seed)


def _attn_weights(rng, d):
    h, kvd = d.hidden, d.kv_dim
    mk = lambda *s: jnp.asarray(rng.standard_normal(s) * (1.0 / np.sqrt(s[-1])),
                                jnp.float32)
    return dict(
        norm1=jnp.ones(h, jnp.float32),
        wq=mk(h, h), wk=mk(kvd, h), wv=mk(kvd, h), wo=mk(h, h),
        norm2=jnp.ones(h, jnp.float32),
    )


def _ffn_weights(rng, d, k=None):
    k = k or d.inter
    h = d.hidden
    mk = lambda *s: jnp.asarray(rng.standard_normal(s) * (1.0 / np.sqrt(s[-1])),
                                jnp.float32)
    return dict(gate=mk(k, h), up=mk(k, h),
                gate_bias=jnp.asarray(rng.standard_normal(k) * 0.1, jnp.float32),
                down=mk(k, h))


class TestRmsNormAndRope:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31))
    def test_rmsnorm_unit_rms(self, seed):
        x = jnp.asarray(_rng(seed).standard_normal((4, 32)) * 3, jnp.float32)
        y = model.rmsnorm(x, jnp.ones(32, jnp.float32))
        rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
        np.testing.assert_allclose(rms, jnp.ones(4), rtol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31), pos=st.integers(0, 100))
    def test_rope_preserves_norm(self, seed, pos):
        x = jnp.asarray(_rng(seed).standard_normal((2, 4, 16)), jnp.float32)
        y = model.rope(x, jnp.full((2,), pos, jnp.int32))
        np.testing.assert_allclose(
            jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-4)

    def test_rope_position_zero_is_identity(self):
        x = jnp.asarray(_rng(1).standard_normal((2, 4, 16)), jnp.float32)
        y = model.rope(x, jnp.zeros((2,), jnp.int32))
        np.testing.assert_allclose(y, x, rtol=1e-6, atol=1e-6)

    def test_rope_matches_ref(self):
        x = jnp.asarray(_rng(2).standard_normal((3, 4, 16)), jnp.float32)
        pos = jnp.asarray([0, 5, 11], jnp.int32)
        np.testing.assert_allclose(
            model.rope(x, pos), ref.ref_rope(x, pos), rtol=1e-5, atol=1e-6)


class TestDecodeAttnGraph:
    def test_shapes_and_per_row_cache_insert(self):
        d = DIMS
        rng = _rng(3)
        w = _attn_weights(rng, d)
        b = 2
        x = jnp.asarray(rng.standard_normal((b, d.hidden)), jnp.float32)
        kc = jnp.zeros((b, d.seq_max, d.kv_heads, d.head_dim), jnp.float32)
        vc = jnp.zeros_like(kc)
        # per-row positions: row 0 writes slot 5, row 1 writes slot 2
        pos = jnp.asarray([5, 2], jnp.int32)
        x_attn, ffn_in, kc2, vc2 = model.decode_attn(
            d, x, w["norm1"], w["wq"], w["wk"], w["wv"], w["wo"], w["norm2"],
            kc, vc, pos)
        assert x_attn.shape == (b, d.hidden)
        assert ffn_in.shape == (b, d.hidden)
        # each row changes only its own position's cache slot
        assert not jnp.allclose(kc2[0, 5], 0.0)
        assert not jnp.allclose(kc2[1, 2], 0.0)
        np.testing.assert_array_equal(kc2[0, :5], 0.0)
        np.testing.assert_array_equal(kc2[0, 6:], 0.0)
        np.testing.assert_array_equal(kc2[1, :2], 0.0)
        np.testing.assert_array_equal(kc2[1, 3:], 0.0)
        np.testing.assert_array_equal(vc2[0, :5], 0.0)
        np.testing.assert_array_equal(vc2[1, :2], 0.0)

    def test_row_output_independent_of_neighbour_position(self):
        """A row's attention output must depend only on its own history —
        the invariant that makes mid-flight admission exact."""
        d = DIMS
        rng = _rng(9)
        w = _attn_weights(rng, d)
        x = jnp.asarray(rng.standard_normal((2, d.hidden)), jnp.float32)
        kc = jnp.asarray(
            rng.standard_normal((2, d.seq_max, d.kv_heads, d.head_dim)) * 0.3,
            jnp.float32)
        vc = jnp.asarray(
            rng.standard_normal((2, d.seq_max, d.kv_heads, d.head_dim)) * 0.3,
            jnp.float32)
        args = [x, w["norm1"], w["wq"], w["wk"], w["wv"], w["wo"], w["norm2"]]
        a, _, _, _ = model.decode_attn(
            d, *args, kc, vc, jnp.asarray([4, 1], jnp.int32))
        b, _, _, _ = model.decode_attn(
            d, *args, kc, vc, jnp.asarray([4, 9], jnp.int32))
        np.testing.assert_allclose(a[0], b[0], rtol=1e-6, atol=1e-6)

    def test_ffn_in_is_normed_x_attn(self):
        d = DIMS
        rng = _rng(4)
        w = _attn_weights(rng, d)
        x = jnp.asarray(rng.standard_normal((1, d.hidden)), jnp.float32)
        kc = jnp.zeros((1, d.seq_max, d.kv_heads, d.head_dim), jnp.float32)
        x_attn, ffn_in, _, _ = model.decode_attn(
            d, x, w["norm1"], w["wq"], w["wk"], w["wv"], w["wo"], w["norm2"],
            kc, jnp.zeros_like(kc), jnp.zeros((1,), jnp.int32))
        np.testing.assert_allclose(
            ffn_in, ref.ref_rmsnorm(x_attn, w["norm2"]), rtol=1e-5, atol=1e-6)


class TestDenseLayerEquivalence:
    def test_dense_layer_equals_attn_plus_full_ffn(self):
        """decode_layer_dense ≡ decode_attn + hot_ffn(I) + residual.

        This is the identity that lets the engine swap the QNN-style dense
        graph for the hybrid split without changing semantics.
        """
        d = DIMS
        rng = _rng(5)
        aw, fw = _attn_weights(rng, d), _ffn_weights(rng, d)
        x = jnp.asarray(rng.standard_normal((2, d.hidden)), jnp.float32)
        kc = jnp.zeros((2, d.seq_max, d.kv_heads, d.head_dim), jnp.float32)
        vc = jnp.zeros_like(kc)
        pos = jnp.asarray([2, 3], jnp.int32)
        args = [x, aw["norm1"], aw["wq"], aw["wk"], aw["wv"], aw["wo"],
                aw["norm2"]]
        y_dense, kc_d, vc_d = model.decode_layer_dense(
            d, *args, fw["gate"], fw["up"], fw["gate_bias"], fw["down"],
            kc, vc, pos)
        x_attn, ffn_in, kc_a, vc_a = model.decode_attn(d, *args, kc, vc, pos)
        y_split = x_attn + model.decode_hot_ffn(
            d, ffn_in, fw["gate"], fw["up"], fw["gate_bias"], fw["down"])
        np.testing.assert_allclose(y_dense, y_split, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(kc_d, kc_a, rtol=1e-6)
        np.testing.assert_allclose(vc_d, vc_a, rtol=1e-6)

    def test_hot_plus_cold_partials_sum_to_full_ffn(self):
        """Splitting I into hot[0:k] on NPU + cold[k:] on CPU is exact."""
        d = DIMS
        rng = _rng(6)
        fw = _ffn_weights(rng, d)
        x = jnp.asarray(rng.standard_normal((2, d.hidden)), jnp.float32)
        full = ref.ref_hot_ffn(x, fw["gate"], fw["up"], fw["gate_bias"],
                               fw["down"])
        k = 64
        hot = ref.ref_hot_ffn(x, fw["gate"][:k], fw["up"][:k],
                              fw["gate_bias"][:k], fw["down"][:k])
        cold = ref.ref_hot_ffn(x, fw["gate"][k:], fw["up"][k:],
                               fw["gate_bias"][k:], fw["down"][k:])
        np.testing.assert_allclose(hot + cold, full, rtol=1e-4, atol=1e-5)


class TestPrefillDecodeConsistency:
    def test_prefill_then_decode_matches_all_prefill(self):
        """Token t computed by decode after a (t)-token prefill must equal
        token t of a (t+1)-token prefill — KV cache install + RoPE offsets
        + masked attention all have to line up for this to hold."""
        d = DIMS
        rng = _rng(7)
        aw, fw = _attn_weights(rng, d), _ffn_weights(rng, d)
        t = d.prefill_chunk
        x_full = jnp.asarray(rng.standard_normal((t, d.hidden)), jnp.float32)

        args_w = [aw["norm1"], aw["wq"], aw["wk"], aw["wv"], aw["wo"],
                  aw["norm2"], fw["gate"], fw["up"], fw["gate_bias"],
                  fw["down"]]
        y_full, k_full, v_full = model.prefill_layer(d, x_full, *args_w)

        # prefill the first t-1 tokens, then decode token t-1
        y_pre, k_pre, v_pre = model.prefill_layer(d, x_full[:t - 1], *args_w)
        kc = jnp.zeros((1, d.seq_max, d.kv_heads, d.head_dim), jnp.float32)
        vc = jnp.zeros_like(kc)
        kc = kc.at[0, :t - 1].set(k_pre)
        vc = vc.at[0, :t - 1].set(v_pre)
        x_attn, ffn_in, kc2, vc2 = model.decode_attn(
            d, x_full[t - 1:t], aw["norm1"], aw["wq"], aw["wk"], aw["wv"],
            aw["wo"], aw["norm2"], kc, vc,
            jnp.full((1,), t - 1, jnp.int32))
        y_dec = x_attn + model.decode_hot_ffn(
            d, ffn_in, fw["gate"], fw["up"], fw["gate_bias"], fw["down"])
        np.testing.assert_allclose(y_dec[0], y_full[t - 1], rtol=2e-3,
                                   atol=2e-4)
        np.testing.assert_allclose(kc2[0, t - 1], k_full[t - 1], rtol=1e-4,
                                   atol=1e-5)


class TestLmHead:
    def test_logits_shape_and_value(self):
        d = DIMS
        rng = _rng(8)
        x = jnp.asarray(rng.standard_normal((2, d.hidden)), jnp.float32)
        nf = jnp.ones(d.hidden, jnp.float32)
        wlm = jnp.asarray(rng.standard_normal((d.vocab, d.hidden)) * 0.05,
                          jnp.float32)
        logits = model.lm_head(d, x, nf, wlm)
        assert logits.shape == (2, d.vocab)
        want = ref.ref_rmsnorm(x, nf) @ wlm.T
        np.testing.assert_allclose(logits, want, rtol=1e-5, atol=1e-5)


class TestGraphTable:
    def test_table_covers_grid(self):
        d = DIMS
        names = [g[0] for g in model.graph_table(d)]
        for b in d.batches:
            assert f"decode_attn_b{b}" in names
            assert f"decode_dense_b{b}" in names
            assert f"lm_head_b{b}" in names
            for k in d.hot_ks:
                assert f"decode_ffn_b{b}_k{k}" in names
        assert f"prefill_layer_t{d.prefill_chunk}" in names
        # (attn + dense + lm_head + ffn·|hot_ks|) per batch + 1 prefill
        assert len(names) == len(d.batches) * (3 + len(d.hot_ks)) + 1

    def test_arg_specs_are_lowerable(self):
        d = DIMS
        for name, fn, arg_specs, _ in model.graph_table(d):
            out = jax.eval_shape(fn, *[s for _, s in arg_specs])
            assert jax.tree_util.tree_leaves(out), name

    def test_validate_rejects_bad_dims(self):
        with pytest.raises(AssertionError):
            model.graph_table(dataclasses.replace(DIMS, hot_ks=(100,)))
        with pytest.raises(AssertionError):
            model.graph_table(dataclasses.replace(DIMS, heads=3))
