"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the compute hot path: hypothesis
sweeps shapes and batch sizes, numpy supplies seeded data, and every case
asserts allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import decode_attention, hot_ffn
from compile.kernels import ref

RTOL, ATOL = 1e-5, 1e-5


def _rng(seed):
    return np.random.default_rng(seed)


def _ffn_inputs(rng, b, h, k):
    x = jnp.asarray(rng.standard_normal((b, h)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((k, h)) * 0.1, jnp.float32)
    u = jnp.asarray(rng.standard_normal((k, h)) * 0.1, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(k) * 0.1, jnp.float32)
    d = jnp.asarray(rng.standard_normal((k, h)) * 0.1, jnp.float32)
    return x, g, u, bias, d


class TestHotFfn:
    @settings(max_examples=16, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 4]),
        h=st.sampled_from([16, 32, 64]),
        blocks=st.integers(1, 4),
        block_k=st.sampled_from([64, 128]),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref_across_shapes(self, b, h, blocks, block_k, seed):
        k = blocks * block_k
        x, g, u, bias, d = _ffn_inputs(_rng(seed), b, h, k)
        got = hot_ffn(x, g, u, bias, d, block_k=block_k)
        want = ref.ref_hot_ffn(x, g, u, bias, d)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_single_block(self):
        x, g, u, bias, d = _ffn_inputs(_rng(0), 2, 32, 128)
        got = hot_ffn(x, g, u, bias, d, block_k=128)
        want = ref.ref_hot_ffn(x, g, u, bias, d)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_rejects_unaligned_cluster(self):
        x, g, u, bias, d = _ffn_inputs(_rng(0), 1, 16, 96)
        with pytest.raises(ValueError, match="multiple"):
            hot_ffn(x, g, u, bias, d, block_k=64)

    def test_zero_input_gives_bias_only_activation(self):
        # x = 0 → pre-act = bias; only positive-bias neurons contribute,
        # and their up-projection is 0, so the output must be exactly 0.
        rng = _rng(1)
        _, g, u, bias, d = _ffn_inputs(rng, 1, 32, 128)
        x = jnp.zeros((1, 32), jnp.float32)
        got = hot_ffn(x, g, u, bias, d, block_k=128)
        np.testing.assert_allclose(got, jnp.zeros_like(got), atol=1e-7)

    def test_negative_bias_kills_neurons(self):
        # Strongly negative gate bias must silence every neuron.
        rng = _rng(2)
        x, g, u, _, d = _ffn_inputs(rng, 2, 32, 128)
        bias = jnp.full((128,), -1e4, jnp.float32)
        got = hot_ffn(x, g, u, bias, d, block_k=128)
        np.testing.assert_allclose(got, jnp.zeros_like(got), atol=1e-7)

    def test_cluster_additivity(self):
        # The FFN output of a 2-block cluster equals the sum of the two
        # 1-block halves — the invariant PowerInfer-2's neuron-cluster
        # decomposition (hot partial on NPU + cold partial on CPU) rests on.
        rng = _rng(3)
        x, g, u, bias, d = _ffn_inputs(rng, 2, 32, 256)
        whole = hot_ffn(x, g, u, bias, d, block_k=128)
        lo = hot_ffn(x, g[:128], u[:128], bias[:128], d[:128], block_k=128)
        hi = hot_ffn(x, g[128:], u[128:], bias[128:], d[128:], block_k=128)
        np.testing.assert_allclose(whole, lo + hi, rtol=1e-4, atol=1e-5)


class TestDecodeAttention:
    def _inputs(self, rng, b, nh, nkv, dh, s):
        q = jnp.asarray(rng.standard_normal((b, nh, dh)), jnp.float32)
        kc = jnp.asarray(rng.standard_normal((b, s, nkv, dh)), jnp.float32)
        vc = jnp.asarray(rng.standard_normal((b, s, nkv, dh)), jnp.float32)
        return q, kc, vc

    @settings(max_examples=16, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 4]),
        nkv=st.sampled_from([1, 2]),
        group=st.sampled_from([1, 2, 4]),
        dh=st.sampled_from([8, 16, 32]),
        s=st.sampled_from([8, 16, 64]),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref_across_shapes(self, b, nkv, group, dh, s, seed):
        rng = _rng(seed)
        nh = nkv * group
        q, kc, vc = self._inputs(rng, b, nh, nkv, dh, s)
        valid = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
        got = decode_attention(q, kc, vc, valid)
        want = ref.ref_decode_attention(q, kc, vc, valid)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_valid_len_one_returns_first_value(self):
        # With one valid cache slot, softmax collapses and the output is
        # exactly v[:, 0] expanded over query heads.
        rng = _rng(4)
        q, kc, vc = self._inputs(rng, 2, 4, 2, 16, 8)
        valid = jnp.asarray([1, 1], jnp.int32)
        got = decode_attention(q, kc, vc, valid)
        want = jnp.repeat(vc[:, 0], 2, axis=1)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_full_cache(self):
        rng = _rng(5)
        q, kc, vc = self._inputs(rng, 1, 8, 2, 32, 64)
        valid = jnp.asarray([64], jnp.int32)
        got = decode_attention(q, kc, vc, valid)
        want = ref.ref_decode_attention(q, kc, vc, valid)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_mask_ignores_garbage_tail(self):
        # Entries past valid_len must not affect the result.
        rng = _rng(6)
        q, kc, vc = self._inputs(rng, 1, 4, 2, 16, 32)
        valid = jnp.asarray([7], jnp.int32)
        base = decode_attention(q, kc, vc, valid)
        kc2 = kc.at[:, 7:].set(1e3)
        vc2 = vc.at[:, 7:].set(-1e3)
        poisoned = decode_attention(q, kc2, vc2, valid)
        np.testing.assert_allclose(base, poisoned, rtol=1e-5, atol=1e-6)

    def test_per_row_valid_lengths_differ(self):
        rng = _rng(7)
        q, kc, vc = self._inputs(rng, 4, 4, 2, 16, 16)
        valid = jnp.asarray([1, 5, 9, 16], jnp.int32)
        got = decode_attention(q, kc, vc, valid)
        want = ref.ref_decode_attention(q, kc, vc, valid)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
