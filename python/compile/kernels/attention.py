"""L1 Pallas kernel: single-step GQA decode attention.

During decoding PowerInfer-2 runs attention on the NPU (the attention
block is dense but small, §4.1.2); this kernel is the NPU-graph form of
one decode step over a ring KV cache:

    out[b, h] = softmax(q[b, h] @ K[b, :len, kv(h)]^T / sqrt(dh)) @ V

The grid iterates over (batch, kv-head); each step loads one batch row of
one KV group — the [S, dh] K/V tiles stream HBM→VMEM while the [G, dh]
query group stays resident — and computes the masked softmax for the G
query heads sharing that KV head. `valid_len` arrives as a [B] int32
vector so the same compiled graph serves any cache fill level (the paper's
static NPU graphs are shape-specialized but length-dynamic in the same
way).

interpret=True for the CPU PJRT plugin; see sparse_ffn.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref):
    """One grid step: one (batch, kv-head) pair.

    q_ref:   [G, dh]  query heads in this KV group
    k_ref:   [S, dh]  cached keys for this batch/kv-head
    v_ref:   [S, dh]  cached values
    len_ref: [1]      valid cache length for this batch row
    o_ref:   [G, dh]  attention output for the group
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    valid = len_ref[0]
    s = k.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    mask = jax.lax.broadcasted_iota(jnp.int32, (s,), 0) < valid
    scores = jnp.where(mask[None, :], scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(probs, v, preferred_element_type=jnp.float32)


@jax.jit
def decode_attention(q, k_cache, v_cache, valid_len):
    """Grouped-query decode attention over a pre-filled KV cache.

    Args:
      q:         [B, NH, DH] roped queries for the new token.
      k_cache:   [B, S, NKV, DH] key cache (new key already inserted).
      v_cache:   [B, S, NKV, DH] value cache.
      valid_len: [B] int32, number of valid cache entries per row.

    Returns:
      [B, NH, DH] attention outputs.
    """
    batch, n_heads, dh = q.shape
    _, seq, n_kv, _ = k_cache.shape
    group = n_heads // n_kv
    grid = (batch, n_kv)
    return pl.pallas_call(
        _decode_attn_kernel,
        grid=grid,
        in_specs=[
            # q viewed as [B, NKV, G, DH]; None dims are squeezed → [G, DH]
            pl.BlockSpec((None, None, group, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, seq, None, dh), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((None, seq, None, dh), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1,), lambda b, h: (b,)),
        ],
        out_specs=pl.BlockSpec((None, None, group, dh), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n_kv, group, dh), jnp.float32),
        interpret=True,
    )(
        q.reshape(batch, n_kv, group, dh),
        k_cache,
        v_cache,
        valid_len,
    ).reshape(batch, n_heads, dh)
