"""L1 Pallas kernel: hot-neuron-cluster GLU FFN.

This is the compute hot-spot of PowerInfer-2's NPU path (§4.1.2): the hot
neuron cluster — the rows of the Gate/Up matrices and the matching columns
of the Down matrix that the offline planner classified as frequently
activated — is evaluated as one dense block:

    y = relu(x @ G^T + b) * (x @ U^T) @ D

where G, U are [K, H] (K = number of hot neurons, H = hidden dim), b is the
per-neuron gate bias [K] (the bias is what gives the model its calibrated
activation sparsity; see rust/src/model/), and D is stored row-major as
[K, H] so that the k-th *bundle* (g_k, u_k, d_k) is contiguous — mirroring
the on-flash Gate-Up-Down bundle layout of §4.4.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles the
hot cluster into the Qualcomm HTP's tightly-coupled memory; here the neuron
dimension K is the Pallas grid axis and each grid step streams one
[BLOCK_K, H] tile of G/U/D from HBM into VMEM, accumulating the output
block in place. On a real TPU the matmuls map onto the MXU; on this image
the kernel runs with interpret=True (the CPU PJRT plugin cannot execute
Mosaic custom-calls) and serves as the canonical definition of the hot
path that `aot.py` lowers into the NPU-graph artifacts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile along the neuron (cluster) dimension. 128 matches the MXU
# systolic-array edge; every hot-cluster size emitted by the planner is a
# multiple of this.
BLOCK_K = 128


def _hot_ffn_kernel(x_ref, g_ref, u_ref, b_ref, d_ref, o_ref):
    """One grid step: accumulate the contribution of one neuron tile.

    x_ref: [B, H]   (same block every step)
    g_ref: [bk, H]  gate rows of this tile
    u_ref: [bk, H]  up rows of this tile
    b_ref: [bk]     gate bias of this tile
    d_ref: [bk, H]  down rows (transposed-out layout) of this tile
    o_ref: [B, H]   output block, revisited by every grid step
    """
    step = pl.program_id(0)
    x = x_ref[...]
    pre = jnp.dot(x, g_ref[...].T, preferred_element_type=jnp.float32)
    pre = pre + b_ref[...][None, :]
    act = jnp.maximum(pre, 0.0) * jnp.dot(
        x, u_ref[...].T, preferred_element_type=jnp.float32
    )
    contrib = jnp.dot(act, d_ref[...], preferred_element_type=jnp.float32)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref[...])

    o_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("block_k",))
def hot_ffn(x, gate, up, gate_bias, down, *, block_k: int = BLOCK_K):
    """Dense GLU FFN over a hot neuron cluster.

    Args:
      x:         [B, H] activations (post-norm FFN input).
      gate:      [K, H] gate projection rows for the cluster.
      up:        [K, H] up projection rows.
      gate_bias: [K]    per-neuron gate bias.
      down:      [K, H] down projection rows (output = act @ down).
      block_k:   tile size along K; K must be a multiple of it.

    Returns:
      [B, H] cluster contribution to the FFN output (no residual).
    """
    batch, hidden = x.shape
    k = gate.shape[0]
    if k % block_k != 0:
        raise ValueError(f"cluster size {k} not a multiple of block_k {block_k}")
    grid = (k // block_k,)
    return pl.pallas_call(
        _hot_ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, hidden), lambda i: (0, 0)),
            pl.BlockSpec((block_k, hidden), lambda i: (i, 0)),
            pl.BlockSpec((block_k, hidden), lambda i: (i, 0)),
            pl.BlockSpec((block_k,), lambda i: (i,)),
            pl.BlockSpec((block_k, hidden), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((batch, hidden), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, hidden), jnp.float32),
        interpret=True,
    )(x, gate, up, gate_bias, down)
