"""L1 Pallas kernels (interpret mode) + pure-jnp oracles."""

from .attention import decode_attention
from .sparse_ffn import hot_ffn

__all__ = ["decode_attention", "hot_ffn"]
