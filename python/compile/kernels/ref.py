"""Pure-jnp oracles for the Pallas kernels and the L2 layer graphs.

Every kernel in this package and every entry point in model.py has a
reference implementation here, written with nothing but jnp primitives in
the most obvious way possible. pytest (python/tests/) asserts allclose
between kernel and oracle across a hypothesis-driven sweep of shapes; the
rust integration tests compare the AOT-compiled artifacts against vectors
produced by these oracles (artifacts/selftest.json).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_hot_ffn(x, gate, up, gate_bias, down):
    """Oracle for kernels.sparse_ffn.hot_ffn."""
    pre = x @ gate.T + gate_bias[None, :]
    act = jnp.maximum(pre, 0.0) * (x @ up.T)
    return act @ down


def ref_decode_attention(q, k_cache, v_cache, valid_len):
    """Oracle for kernels.attention.decode_attention (GQA, masked)."""
    batch, n_heads, dh = q.shape
    _, seq, n_kv, _ = k_cache.shape
    group = n_heads // n_kv
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    # expand kv heads to query heads
    k = jnp.repeat(k_cache, group, axis=2)  # [B, S, NH, DH]
    v = jnp.repeat(v_cache, group, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q, k) * scale
    mask = jnp.arange(seq)[None, None, :] < valid_len[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", probs, v)


def ref_rmsnorm(x, gamma, eps=1e-5):
    """Oracle RMSNorm (matches model.rmsnorm)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gamma


def ref_rope(x, positions, theta=10000.0):
    """Oracle rotary embedding.

    x: [..., n_heads, dh]; positions: broadcastable to x[..., 0, 0].
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def ref_prefill_attention(q, k, v):
    """Causal full-sequence GQA attention. q [T,NH,DH], k/v [T,NKV,DH]."""
    t, n_heads, dh = q.shape
    n_kv = k.shape[1]
    group = n_heads // n_kv
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("thd,shd->hts", q, kx) * scale
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(causal[None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hts,shd->thd", probs, vx)
