"""AOT lowering: the NPU-graph table → artifacts/*.hlo.txt + manifest.

Runs ONCE at build time (`make artifacts`); python is never on the request
path. For every graph in model.graph_table() this script:

  1. jits + lowers the function to StableHLO,
  2. converts it to an XlaComputation and dumps HLO **text** —
     xla_extension 0.5.1 (the version the published `xla` crate binds)
     rejects jax≥0.5's serialized HloModuleProto (64-bit instruction ids);
     the text parser reassigns ids, so text round-trips cleanly
     (see /opt/xla-example/README.md),
  3. records name/arg-shapes/metadata in artifacts/manifest.json, which the
     rust runtime reads to compile and index the executables.

It also emits:
  * model_config.json — the ModelDims the rust side must mirror,
  * selftest/ — a tiny-dims graph table plus seeded input/output vectors
    (selftest.json) that rust integration tests replay through PJRT to
    prove the full AOT bridge is numerically sound.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import ModelDims, graph_table

SELFTEST_DIMS = ModelDims(
    hidden=32,
    inter=256,
    layers=2,
    heads=4,
    kv_heads=2,
    vocab=64,
    seq_max=16,
    prefill_chunk=8,
    batches=(1, 2),
    hot_ks=(128, 256),
    # paged KV: 4-token blocks; 8 leasable blocks + 1 reserved scratch
    # (the dense equivalent of 2 batch rows × 4 blocks per sequence)
    kv_block=4,
    kv_blocks=9,
)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_graph(fn, arg_specs):
    specs = [spec for _, spec in arg_specs]
    return jax.jit(fn).lower(*specs)


def emit_table(dims: ModelDims, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for name, fn, arg_specs, meta in graph_table(dims):
        lowered = lower_graph(fn, arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(fn, *[s for _, s in arg_specs])
        outs = jax.tree_util.tree_leaves(out_tree)
        entries.append({
            "name": name,
            "file": fname,
            "meta": meta,
            "args": [
                {"name": an, "shape": list(s.shape), "dtype": s.dtype.name}
                for an, s in arg_specs
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": o.dtype.name} for o in outs
            ],
        })
        print(f"  {name}: {len(text)} chars, {len(arg_specs)} args, "
              f"{len(outs)} outputs")
    return {
        "dims": dataclasses.asdict(dims),
        "graphs": entries,
    }


def _rand_for_spec(rng, name, spec, dims):
    if spec.dtype == jnp.int32:
        if name == "block_table":
            # disjoint, valid physical blocks per row (never the reserved
            # scratch block 0, never out of pool range) — deterministic so
            # the per-row scatter/gather paths replay bit-exactly in rust
            b, m = spec.shape
            vals = 1 + np.arange(b * m, dtype=np.int32) % (dims.kv_blocks - 1)
            return vals.reshape(b, m)
        # the [B] per-row `pos` vector and the prefill chunk's [1] `start`
        # offset; keep every position small and valid (distinct values
        # exercise the per-row insert/mask and prefix-mask paths)
        return rng.integers(0, 4, size=spec.shape, dtype=np.int32)
    scale = 0.25
    return (rng.standard_normal(spec.shape) * scale).astype(np.float32)


def emit_selftest(out_dir: str) -> None:
    """Tiny-dims artifacts + seeded input/expected-output vectors."""
    dims = SELFTEST_DIMS
    st_dir = os.path.join(out_dir, "selftest")
    manifest = emit_table(dims, st_dir)
    rng = np.random.default_rng(2024)
    cases = []
    for name, fn, arg_specs, _meta in graph_table(dims):
        if not ("_b1" in name or name.startswith("prefill")):
            continue
        inputs = [_rand_for_spec(rng, an, spec, dims) for an, spec in arg_specs]
        outputs = jax.tree_util.tree_leaves(fn(*[jnp.asarray(v) for v in inputs]))
        cases.append({
            "graph": name,
            "inputs": [
                {"shape": list(np.shape(v)), "dtype": str(np.asarray(v).dtype),
                 "data": np.asarray(v, dtype=np.float64).ravel().tolist()
                 if np.asarray(v).dtype != np.int32
                 else np.asarray(v).ravel().tolist()}
                for v in inputs
            ],
            "outputs": [
                {"shape": list(o.shape),
                 "data": np.asarray(o, dtype=np.float64).ravel().tolist()}
                for o in outputs
            ],
        })
    manifest["cases"] = [c["graph"] for c in cases]
    with open(os.path.join(st_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(st_dir, "selftest.json"), "w") as f:
        json.dump({"dims": dataclasses.asdict(dims), "cases": cases}, f)
    print(f"selftest: {len(cases)} cases")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--hidden", type=int)
    p.add_argument("--inter", type=int)
    p.add_argument("--layers", type=int)
    p.add_argument("--vocab", type=int)
    p.add_argument("--seq-max", type=int)
    p.add_argument("--skip-selftest", action="store_true")
    args = p.parse_args()

    overrides = {
        k: v for k, v in (
            ("hidden", args.hidden), ("inter", args.inter),
            ("layers", args.layers), ("vocab", args.vocab),
            ("seq_max", args.seq_max),
        ) if v is not None
    }
    dims = dataclasses.replace(ModelDims(), **overrides)

    print(f"emitting NPU graph table for dims={dims}")
    manifest = emit_table(dims, args.out)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(args.out, "model_config.json"), "w") as f:
        json.dump(dataclasses.asdict(dims), f, indent=1)
    if not args.skip_selftest:
        emit_selftest(args.out)
    print("done")


if __name__ == "__main__":
    main()
