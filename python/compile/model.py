"""L2: the JAX model — transformer layer graphs that become NPU artifacts.

PowerInfer-2 pre-builds a table of *static NPU computation graphs*, one per
(batch size, hot-neuron ratio) operating point (§4.1.3); switching the
CPU/NPU split ratio at runtime means activating a different pre-built
graph. We reproduce that table literally: every function below is lowered
by aot.py into one HLO-text artifact per grid point, and the rust runtime
(rust/src/runtime/) compiles each once on the PJRT CPU client and switches
between the resulting executables.

Graph inventory (kind → role in the paper):

  prefill_chunk      NPU-centric chunked prefill (§4.1.1, pipelined à la
                     §4.2's neuron clusters): one dense transformer layer
                     over a T-token prompt *chunk* starting at absolute
                     position start, attending over the already-installed
                     prompt prefix (passed in as k_prev/v_prev rows
                     0..start) plus the chunk itself (causal). start = 0
                     with an empty prefix is a whole-prompt prefill; the
                     serving layer slices long prompts into bounded
                     chunks so in-flight decodes interleave with prompt
                     installation instead of stalling behind it. Returns
                     the layer output plus the roped K/V rows to install
                     at positions start..start+T.
  decode_attn        decode-phase attention (§4.1.2): RMSNorm → QKV →
                     RoPE → paged cache insert through a per-row block
                     table into the shared KV pool → gather → GQA
                     attention (Pallas kernel) → output proj → residual;
                     also emits the FFN-normed hidden state that both the
                     NPU hot path and the CPU cold path consume. KV is
                     paged: one [kv_blocks, kv_block, NKV, DH] pool per
                     layer, a [B, seq_max/kv_block] int32 block table,
                     and the [B] per-row position vector.
  decode_hot_ffn     the NPU side of the hybrid FFN: dense GLU over the
                     hot neuron cluster (Pallas hot_ffn kernel). The cold
                     (sparse, predictor-gated) side is NOT an HLO graph —
                     it runs natively on the rust CPU path, mirroring the
                     paper's NPU-dense / CPU-sparse split.
  decode_layer_dense dense full-FFN decode layer, used by the QNN-style
                     NPU-only baseline and as the ratio=1.0 grid point.
  lm_head            final RMSNorm + vocabulary projection.

All weights are graph *inputs*, not constants: on the phone the NPU reads
weights from UMA shared memory that the CPU-side cache manager populates
(§4.2); here the rust cache manager owns the buffers and passes them per
call.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import decode_attention, hot_ffn
from .kernels.sparse_ffn import BLOCK_K

F32 = jnp.float32


@dataclass(frozen=True)
class ModelDims:
    """Geometry of the e2e model (a scaled-down Bamboo/Mistral shape).

    The simulation-side ModelSpec presets in rust/src/config/ carry the
    papers' true 7B/13B/47B shapes; this one is the model that actually
    runs through PJRT in the end-to-end example.
    """

    hidden: int = 512
    inter: int = 2048          # FFN neurons per layer (I)
    layers: int = 8
    heads: int = 8
    kv_heads: int = 2
    vocab: int = 4096
    seq_max: int = 256         # logical KV window per sequence (S)
    prefill_chunk: int = 64    # T
    batches: tuple = (1, 2, 4)
    # hot-cluster sizes (rows) the planner may pick; all multiples of BLOCK_K
    hot_ks: tuple = (512, 1024, 1536, 2048)
    # paged KV: the cache is one shared pool of kv_blocks physical blocks
    # of kv_block tokens each (block 0 is the reserved scratch block that
    # vacant batch rows write into); each sequence maps up to
    # seq_max/kv_block blocks through its row of the block table
    kv_block: int = 16
    kv_blocks: int = 65
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim

    @property
    def max_blocks(self) -> int:
        """Block-table width: pool blocks one sequence may map."""
        return self.seq_max // self.kv_block

    def validate(self) -> None:
        assert self.hidden % self.heads == 0
        assert self.heads % self.kv_heads == 0
        for k in self.hot_ks:
            assert k % BLOCK_K == 0 and k <= self.inter
        assert self.inter % BLOCK_K == 0
        assert self.seq_max % self.kv_block == 0
        assert self.kv_blocks >= 2


def rmsnorm(x, gamma, eps=1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gamma


def rope(x, positions, theta=10000.0):
    """Rotary embedding. x: [..., n_heads, dh]; positions broadcastable."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    angles = positions[..., None].astype(F32) * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# decode-phase graphs
# ---------------------------------------------------------------------------


def decode_attn(dims: ModelDims, x, norm1, wq, wk, wv, wo, norm2,
                k_pool, v_pool, block_table, pos):
    """Attention block for one decode step over the paged KV pool.

    Args:
      x:           [B, H] residual stream.
      norm1/2:     [H] RMSNorm gains (pre-attn / pre-FFN).
      wq:          [H, H]; wk, wv: [KVD, H]; wo: [H, H].
      k_pool:      [NB, BS, NKV, DH] shared block pool; v_pool likewise.
      block_table: [B, M] int32 — row i's logical→physical block mapping
                   (M = seq_max/kv_block). Unused entries point at the
                   reserved scratch block 0.
      pos:         [B] int32 — per-row index of the new token (cache
                   insert slot / RoPE offset). Rows are independent
                   sequences, so a row admitted mid-flight attends only
                   over its own blocks (continuous batching), and rows
                   with identical prompt prefixes may map the same
                   physical blocks (prefix sharing).

    Returns:
      (x_attn [B,H], ffn_in [B,H], k_pool', v_pool')
    """
    b = x.shape[0]
    nh, nkv, dh = dims.heads, dims.kv_heads, dims.head_dim
    bs = dims.kv_block
    h = rmsnorm(x, norm1, dims.norm_eps)
    q = (h @ wq.T).reshape(b, nh, dh)
    k = (h @ wk.T).reshape(b, nkv, dh)
    v = (h @ wv.T).reshape(b, nkv, dh)
    q = rope(q, pos, dims.rope_theta)
    k = rope(k, pos, dims.rope_theta)
    # paged cache insert: row i writes its new K/V into physical block
    # table[i, pos//BS] at offset pos%BS (one batched scatter per pool —
    # constant graph size in B)
    rows = jnp.arange(b)
    blk = block_table[rows, pos // bs]
    off = pos % bs
    k_pool = k_pool.at[blk, off].set(k)
    v_pool = v_pool.at[blk, off].set(v)
    # gather each row's logical window through its block table:
    # [NB, BS, ...][B, M] → [B, M, BS, ...] → [B, S, ...]
    k_cache = k_pool[block_table].reshape(b, dims.seq_max, nkv, dh)
    v_cache = v_pool[block_table].reshape(b, dims.seq_max, nkv, dh)
    valid = pos + 1
    attn = decode_attention(q, k_cache, v_cache, valid)
    y = attn.reshape(b, nh * dh) @ wo.T
    x_attn = x + y
    ffn_in = rmsnorm(x_attn, norm2, dims.norm_eps)
    return x_attn, ffn_in, k_pool, v_pool


def decode_hot_ffn(dims: ModelDims, ffn_in, gate, up, gate_bias, down):
    """NPU hot-cluster FFN partial: [B,H] × hot cluster → [B,H]."""
    return hot_ffn(ffn_in, gate, up, gate_bias, down, block_k=BLOCK_K)


def decode_layer_dense(dims: ModelDims, x, norm1, wq, wk, wv, wo, norm2,
                       gate, up, gate_bias, down, k_pool, v_pool,
                       block_table, pos):
    """Full dense decode layer (attention + full-I FFN + residuals).

    `block_table`/`pos` are the paged-KV args, as in `decode_attn`.
    """
    x_attn, ffn_in, k_pool, v_pool = decode_attn(
        dims, x, norm1, wq, wk, wv, wo, norm2, k_pool, v_pool,
        block_table, pos)
    y = hot_ffn(ffn_in, gate, up, gate_bias, down, block_k=BLOCK_K)
    return x_attn + y, k_pool, v_pool


def lm_head(dims: ModelDims, x, norm_f, w_lm):
    """Final norm + logits. x [B,H], w_lm [V,H] → [B,V]."""
    return rmsnorm(x, norm_f, dims.norm_eps) @ w_lm.T


# ---------------------------------------------------------------------------
# prefill-phase graph
# ---------------------------------------------------------------------------


def prefill_chunk(dims: ModelDims, x, norm1, wq, wk, wv, wo, norm2,
                  gate, up, gate_bias, down, k_prev, v_prev, start):
    """One dense transformer layer over a T-token prompt chunk.

    x: [T, H] — the chunk's token embeddings / hidden state (single
    sequence; the paper prefills one prompt at a time). The chunk sits at
    absolute positions start..start+T of its sequence, and attends over

      * the already-installed prompt prefix: k_prev/v_prev [S, NKV, DH]
        (S = seq_max), roped K as stored in the KV pool, valid in rows
        0..start (rows beyond start are zero padding and masked out), and
      * the chunk itself, causally.

    start: [1] int32 — the chunk's first absolute position (RoPE offset
    and prefix-mask length). start = 0 with zeroed k_prev/v_prev is
    exactly a whole-prompt prefill, so one graph serves both the
    synchronous and the chunked admission paths.

    Returns (x_out [T,H], k [T,NKV,DH], v [T,NKV,DH]) — the caller
    installs the roped k/v rows into the sequence's leased pool blocks at
    positions start..start+T and feeds x_out to the next layer's chunk.
    Right-padded chunks (fewer than T real tokens) are fine: a padded
    query's output is garbage but attends only backwards, so real rows
    are unaffected and the caller simply ignores rows past its length.
    """
    t = x.shape[0]
    s = dims.seq_max
    nh, nkv, dh = dims.heads, dims.kv_heads, dims.head_dim
    h = rmsnorm(x, norm1, dims.norm_eps)
    q = (h @ wq.T).reshape(t, nh, dh)
    k = (h @ wk.T).reshape(t, nkv, dh)
    v = (h @ wv.T).reshape(t, nkv, dh)
    positions = start[0] + jnp.arange(t, dtype=jnp.int32)
    q = rope(q, positions, dims.rope_theta)
    k = rope(k, positions, dims.rope_theta)

    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    group = nh // nkv
    # key axis = [installed prefix (S rows, start valid) ++ chunk (T rows)]
    kx = jnp.concatenate(
        [jnp.repeat(k_prev, group, axis=1), jnp.repeat(k, group, axis=1)],
        axis=0)
    vx = jnp.concatenate(
        [jnp.repeat(v_prev, group, axis=1), jnp.repeat(v, group, axis=1)],
        axis=0)
    scores = jnp.einsum("thd,shd->hts", q, kx) * scale
    # prefix keys visible iff their absolute position < start (they all
    # precede every chunk query); chunk keys causally within the chunk
    key_idx = jnp.arange(s + t)
    prefix_valid = key_idx[None, :] < start[0]
    chunk_causal = (key_idx[None, :] - s) <= jnp.arange(t)[:, None]
    mask = jnp.where(key_idx[None, :] < s, prefix_valid, chunk_causal)
    scores = jnp.where(mask[None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    attn = jnp.einsum("hts,shd->thd", probs, vx)

    x_attn = x + attn.reshape(t, nh * dh) @ wo.T
    ffn_in = rmsnorm(x_attn, norm2, dims.norm_eps)
    y = hot_ffn(ffn_in, gate, up, gate_bias, down, block_k=BLOCK_K)
    return x_attn + y, k, v


# ---------------------------------------------------------------------------
# shape helpers for aot.py
# ---------------------------------------------------------------------------


def _s(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), F32)


def _si(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32)


def attn_weight_specs(d: ModelDims):
    return [
        ("norm1", _s(d.hidden)),
        ("wq", _s(d.hidden, d.hidden)),
        ("wk", _s(d.kv_dim, d.hidden)),
        ("wv", _s(d.kv_dim, d.hidden)),
        ("wo", _s(d.hidden, d.hidden)),
        ("norm2", _s(d.hidden)),
    ]


def ffn_weight_specs(d: ModelDims, k: int):
    return [
        ("gate", _s(k, d.hidden)),
        ("up", _s(k, d.hidden)),
        ("gate_bias", _s(k)),
        ("down", _s(k, d.hidden)),
    ]


def graph_table(d: ModelDims):
    """The full NPU-graph table: list of (name, fn, arg specs, meta)."""
    d.validate()
    graphs = []

    pool = _s(d.kv_blocks, d.kv_block, d.kv_heads, d.head_dim)
    for b in d.batches:
        paged = [("k_pool", pool), ("v_pool", pool),
                 ("block_table", _si(b, d.max_blocks)), ("pos", _si(b))]
        args = ([("x", _s(b, d.hidden))] + attn_weight_specs(d) + paged)
        graphs.append((
            f"decode_attn_b{b}",
            lambda *a, _d=d: decode_attn(_d, *a),
            args,
            {"kind": "decode_attn", "batch": b},
        ))

        for k in d.hot_ks:
            args = [("ffn_in", _s(b, d.hidden))] + ffn_weight_specs(d, k)
            graphs.append((
                f"decode_ffn_b{b}_k{k}",
                lambda *a, _d=d: decode_hot_ffn(_d, *a),
                args,
                {"kind": "decode_hot_ffn", "batch": b, "hot_k": k},
            ))

        args = ([("x", _s(b, d.hidden))] + attn_weight_specs(d)
                + ffn_weight_specs(d, d.inter) + paged)
        graphs.append((
            f"decode_dense_b{b}",
            lambda *a, _d=d: decode_layer_dense(_d, *a),
            args,
            {"kind": "decode_layer_dense", "batch": b},
        ))

        args = [("x", _s(b, d.hidden)),
                ("norm_f", _s(d.hidden)),
                ("w_lm", _s(d.vocab, d.hidden))]
        graphs.append((
            f"lm_head_b{b}",
            lambda *a, _d=d: lm_head(_d, *a),
            args,
            {"kind": "lm_head", "batch": b},
        ))

    t = d.prefill_chunk
    prev = _s(d.seq_max, d.kv_heads, d.head_dim)
    args = ([("x", _s(t, d.hidden))] + attn_weight_specs(d)
            + ffn_weight_specs(d, d.inter)
            + [("k_prev", prev), ("v_prev", prev), ("start", _si(1))])
    graphs.append((
        f"prefill_chunk_t{t}",
        lambda *a, _d=d: prefill_chunk(_d, *a),
        args,
        {"kind": "prefill_chunk", "tokens": t},
    ))

    return graphs
