"""Build-time compile path: L2 JAX model + L1 Pallas kernels → HLO text.

Nothing in this package is imported at runtime; the rust coordinator only
consumes the artifacts/ directory this package produces.
"""
